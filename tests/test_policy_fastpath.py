"""Policy hot path: flattened/vectorized trees, the tabulated predictor's
on-grid-exactness contract, the vectorized allocator's equivalence with the
scalar path, the integer-FFD fast path, and the simulator's incremental
bandwidth accounting — plus the regression pin that the vectorized
allocator's objectives stay >= the PR 2 scalar snapshots on ``dag_suite``.
"""
from dataclasses import dataclass

import numpy as np
import pytest

from repro.core import (CamelotAllocator, CommModel, DecisionTreeRegressor,
                        PipelinePredictor, RandomForestRegressor, RTX_2080TI,
                        SAConfig, StagePredictor, TabulatedStagePredictor,
                        collect_samples)
from repro.core.allocator import QUOTA_STEP, _ffd_fits, _ffd_fits_units
from repro.core.types import (MicroserviceProfile, ServiceEdge, ServiceGraph)
from repro.sim import PipelineSimulator, SimConfig, dag_suite, even_allocation
from repro.sim.workloads import artifact_stage, camelot_suite


# --------------------------------------------------------------------------
# flattened trees: vectorized predict is bit-identical to the node walk
# --------------------------------------------------------------------------

def _toy_data(seed=0, n=300):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 1, size=(n, 2))
    y = np.sin(x[:, 0] * 8) * np.cos(x[:, 1] * 5) + rng.normal(0, 0.05, n)
    return x, y


@pytest.mark.parametrize("depth", [1, 4, 12])
def test_flat_tree_predict_bit_identical(depth):
    x, y = _toy_data()
    dt = DecisionTreeRegressor(max_depth=depth, seed=depth).fit(x, y)
    xq = np.random.default_rng(1).uniform(-0.2, 1.2, size=(500, 2))
    assert (dt.predict(xq) == dt._predict_recursive(xq)).all()


def test_flat_tree_single_leaf():
    x, y = _toy_data(n=6)
    dt = DecisionTreeRegressor().fit(x, np.ones(6))      # constant target
    assert (dt.predict(x) == 1.0).all()


def test_forest_stacked_predict_bit_identical():
    x, y = _toy_data(2)
    rf = RandomForestRegressor(n_trees=9, max_depth=8, seed=3).fit(x, y)
    xq = np.random.default_rng(4).uniform(0, 1, size=(200, 2))
    # one (T, N) arena walk == the mean of per-tree reference walks, bit
    # for bit (same comparisons, same reduction)
    ref = np.mean([t._predict_recursive(xq) for t in rf.trees], axis=0)
    assert (rf.predict(xq) == ref).all()


# --------------------------------------------------------------------------
# tabulated predictor: exact on-grid, model fallback off-grid
# --------------------------------------------------------------------------

def _fit_pair(batches=(1, 2, 4, 8, 16)):
    prof = artifact_stage("c", 2)
    samples = collect_samples(prof, RTX_2080TI, batches=batches, seed=7)
    scalar = StagePredictor("s", "dt", seed=7).fit(samples, profile=prof)
    tab = TabulatedStagePredictor("s", "dt", seed=7).fit(samples,
                                                         profile=prof)
    return scalar, tab, batches


def test_tabulated_exact_on_grid():
    scalar, tab, batches = _fit_pair()
    quotas = np.round(np.arange(QUOTA_STEP, 1.0 + 1e-9, QUOTA_STEP), 2)
    for b in batches:
        for q in quotas:
            for metric in ("duration", "bandwidth", "throughput"):
                assert getattr(tab, metric)(b, float(q)) == \
                    getattr(scalar, metric)(b, float(q)), (b, q, metric)


def test_tabulated_off_grid_falls_back_to_model():
    scalar, tab, _ = _fit_pair()
    for b, q in ((5, 0.5), (8, 0.33), (7, 0.17)):     # off lattice / grid
        assert tab.duration(b, q) == scalar.duration(b, q)
        assert tab.throughput(b, q) == scalar.throughput(b, q)


def test_quota_row_matches_scalar_calls():
    scalar, tab, _ = _fit_pair()
    grid = np.round(np.arange(QUOTA_STEP, 1.0 + 1e-9, QUOTA_STEP), 2)
    row = tab.quota_row("duration", 8, grid)
    ref = np.array([scalar.duration(8, float(q)) for q in grid])
    assert (row == ref).all()
    # off-lattice batch: still served (by the model), still correct
    row5 = tab.quota_row("duration", 5, grid)
    ref5 = np.array([scalar.duration(5, float(q)) for q in grid])
    assert (row5 == ref5).all()


def test_predict_time_accumulates_and_resets():
    scalar, _, _ = _fit_pair()
    scalar.reset_counters()
    assert scalar.predict_time == 0.0 and scalar.predict_calls == 0
    scalar.duration(8, 0.5)
    t1 = scalar.predict_time
    scalar.duration(8, 0.5)
    assert scalar.predict_time > t1          # accumulates, not overwritten
    assert scalar.predict_calls == 2
    scalar.reset_counters()
    assert scalar.predict_time == 0.0 and scalar.predict_calls == 0


def test_collect_samples_hoists_ground_truth():
    calls = []

    @dataclass(frozen=True)
    class CountingProfile(MicroserviceProfile):
        def duration(self, batch, quota, device):
            calls.append((batch, quota))
            return super().duration(batch, quota, device)

    prof = CountingProfile(
        name="c", flops_per_query=10e9, mem_bytes_per_query=40e6,
        host_bytes_per_query=1e6, weights_bytes=500e6,
        act_bytes_per_query=24e6)
    batches, quotas = (1, 4), (0.25, 0.5)
    collect_samples(prof, RTX_2080TI, batches=batches, quotas=quotas,
                    repeats=3)
    # one deterministic curve evaluation per (batch, quota) — repeats only
    # redraw the measurement noise
    assert len(calls) == len(batches) * len(quotas)


# --------------------------------------------------------------------------
# allocator: batched candidate evaluation == the scalar _eval
# --------------------------------------------------------------------------

def _alloc_for(graph, n_devices=4, mode="vectorized", iterations=400):
    pred = PipelinePredictor.from_graph(graph, RTX_2080TI,
                                        batches=(1, 4, 8, 16))
    return CamelotAllocator(graph, pred, RTX_2080TI, n_devices,
                            comm=CommModel(RTX_2080TI),
                            sa=SAConfig(iterations=iterations, seed=0,
                                        mode=mode))


def test_eval_many_matches_scalar_eval():
    g = dag_suite()["diamond"]
    alloc = _alloc_for(g)
    batch, nd = 8, 4
    tab = alloc._policy_tables(batch)
    rng = np.random.default_rng(0)
    n = g.n_nodes
    checked_feasible = 0
    for _ in range(300):
        # biased towards small quotas so the sweep also hits feasible states
        ns = rng.integers(1, 7, size=n)
        qi = rng.integers(0, 8, size=n)
        ps = tab.grid[qi]
        ev = alloc._eval(ns, ps, batch, nd)
        thpt, quota, lat, feas = alloc._eval_many(ns[None], qi[None], tab,
                                                  nd)
        assert bool(feas[0]) == (ev is not None)
        if ev is not None:
            checked_feasible += 1
            assert thpt[0] == pytest.approx(ev[0], rel=1e-12)
            assert quota[0] == pytest.approx(ev[1], rel=1e-12)
            assert lat[0] == pytest.approx(ev[2], rel=1e-12)
    assert checked_feasible > 10         # the sweep hit real feasible states


def test_ffd_units_equals_float_ffd():
    rng = np.random.default_rng(5)
    for _ in range(500):
        qi = rng.integers(0, 20, size=int(rng.integers(1, 7)))
        ns = rng.integers(1, 20, size=len(qi))
        nd = int(rng.integers(1, 6))
        counts = np.bincount(qi, weights=ns,
                             minlength=20).astype(np.int64).tolist()
        quotas = np.round((qi + 1) * QUOTA_STEP, 2).repeat(ns)
        assert _ffd_fits(quotas, nd) == _ffd_fits_units(counts, nd)


def test_critical_path_arrays_matches_scalar():
    nodes = [None] * 5
    edges = [ServiceEdge(0, 1), ServiceEdge(0, 2), ServiceEdge(1, 3),
             ServiceEdge(2, 3), ServiceEdge(3, 4), ServiceEdge(0, 4)]
    g = ServiceGraph("x", nodes, edges, qos_target=1.0)
    rng = np.random.default_rng(6)
    nc = rng.uniform(0.1, 1.0, size=(32, 5))
    ec = rng.uniform(0.0, 0.3, size=(32, len(edges)))
    batched = g.critical_path_arrays(nc, ec)
    for k in range(32):
        ref = g.critical_path(
            node_cost=lambda i, k=k: float(nc[k, i]),
            edge_cost=lambda e, k=k: float(
                ec[k, g._edge_index[(e.src, e.dst)]]))
        assert batched[k] == pytest.approx(ref, rel=1e-12)


# --------------------------------------------------------------------------
# regression pin: vectorized objectives >= the PR 2 scalar snapshots
# --------------------------------------------------------------------------

# scalar-path solve_max_load objectives measured at the PR 2 commit
# (SAConfig(iterations=800, seed=0), batch=8, 4 devices, profiling batches
# (1, 4, 8, 16)); ensemble-6 joined the suite with this PR, pinned at its
# introduction value
_PR2_SNAPSHOT = {
    "diamond": 1002.088042,
    "backbone-3h": 1067.225898,
    "ensemble-6": 1035.608,
}


def test_vectorized_objectives_ge_pr2_snapshots():
    for name, g in dag_suite().items():
        res = _alloc_for(g, mode="vectorized",
                         iterations=800).solve_max_load(batch=8)
        assert res.feasible, name
        assert res.objective >= _PR2_SNAPSHOT[name] * 0.99, \
            (name, res.objective)
        assert res.mode == "vectorized"
        assert res.predictor_time >= 0.0


def test_scalar_mode_still_available():
    pipe = camelot_suite()["img-to-img"]
    pred = PipelinePredictor.from_profiles(pipe.stages, RTX_2080TI,
                                           tabulate=False)
    res = CamelotAllocator(pipe, pred, RTX_2080TI, 2,
                           sa=SAConfig(iterations=300, seed=0,
                                       mode="scalar")).solve_max_load(16)
    assert res.feasible and res.mode == "scalar"
    # the scalar path pays real per-call model inference, and the solve
    # reports it
    assert res.predictor_time > 0.0


# --------------------------------------------------------------------------
# simulator: incremental bandwidth accounting == the legacy scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("qps", [40.0, 400.0])
def test_sim_incremental_bw_matches_scan(qps):
    pipe = camelot_suite()["img-to-img"]
    alloc, comm = even_allocation(pipe, RTX_2080TI, 2, batch=8)
    out = {}
    for inc in (True, False):
        r = PipelineSimulator(
            pipe, alloc, RTX_2080TI, comm,
            sim=SimConfig(duration=4.0, warmup=0.5, seed=0,
                          incremental_bw=inc)).run(qps)
        out[inc] = (r.p99, r.mean_latency, r.completed, r.achieved_qps,
                    r.events)
    assert out[True] == out[False]
    assert out[True][4] > 0              # events are counted
