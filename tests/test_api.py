"""The `repro.camelot` facade: spec round-tripping, session end-to-end
parity with the hand-wired layers, and policy-registry dispatch.

The parity tests are the facade's core contract: driving the loop through
``CamelotSession`` + the policy registry must produce the SAME allocation
and the SAME simulated latencies as wiring ``PipelinePredictor`` →
``CamelotAllocator`` → ``PipelineSimulator`` by hand — the facade only
wires, it never changes results.
"""
import json

import numpy as np
import pytest

from repro.camelot import (CamelotSession, ClusterSpec, LoadSpec,
                           MaxPeakPolicy, QoSSpec, SAConfig, ServiceSpec,
                           UnknownPolicyError, available_policies,
                           get_policy, register_policy)
from repro.camelot.policies import _REGISTRY
from repro.core import (CamelotAllocator, CommModel, PipelinePredictor,
                        RTX_2080TI)
from repro.core.types import MicroserviceProfile, Pipeline, ServiceEdge
from repro.sim import PipelineSimulator, SimConfig, dag_suite
from repro.sim.baselines import even_allocation
from repro.sim.workloads import workload_specs

SA = SAConfig(iterations=500, seed=0)


# --------------------------------------------------------------------------
# Spec round-tripping
# --------------------------------------------------------------------------

ALL_SPECS = workload_specs(include_artifacts=True)


@pytest.mark.parametrize("name", sorted(ALL_SPECS))
def test_service_spec_roundtrip(name):
    spec = ALL_SPECS[name]
    assert ServiceSpec.from_dict(spec.to_dict()) == spec
    # through JSON: the dict must be plain serialisable data
    assert ServiceSpec.from_dict(json.loads(json.dumps(
        spec.to_dict()))) == spec


@pytest.mark.parametrize("name", sorted(dag_suite()))
def test_dag_spec_build_matches_source_graph(name):
    graph = dag_suite()[name]
    spec = ServiceSpec.from_dict(ServiceSpec.from_graph(graph).to_dict())
    built = spec.build()
    assert built.name == graph.name
    assert built.nodes == list(graph.nodes)
    assert built.edges == list(graph.edges)
    assert built.qos_target == graph.qos_target
    assert built.topo_order == graph.topo_order


def test_chain_shorthand():
    nodes = list(ALL_SPECS["img-to-img"].nodes)
    spec = ServiceSpec.chain("c", nodes, qos_target=0.2)
    assert spec.is_chain
    # from_dict with the "chain" shorthand (or no edges key at all)
    d = spec.to_dict()
    d["edges"] = "chain"
    assert ServiceSpec.from_dict(d) == spec
    del d["edges"]
    assert ServiceSpec.from_dict(d) == spec
    assert isinstance(spec.build(), Pipeline)
    with pytest.raises(ValueError):
        ServiceSpec.from_dict({**spec.to_dict(), "edges": "ring"})


def test_payload_override_survives_roundtrip_and_build():
    nodes = list(ALL_SPECS["img-to-img"].nodes)
    spec = ServiceSpec("p", nodes, (ServiceEdge(0, 1, 123.0),))
    back = ServiceSpec.from_dict(spec.to_dict())
    assert back.edges[0].payload_bytes_per_query == 123.0
    assert back.build().edge_nbytes(0, 1, 4) == 123.0 * 4


def test_cluster_spec_roundtrip_and_quantize():
    c = ClusterSpec(devices=4, quota_step=0.05, pcie_total=10e9,
                    global_memory=False)
    assert ClusterSpec.from_dict(c.to_dict()) == c
    assert ClusterSpec.from_dict(json.loads(json.dumps(c.to_dict()))) == c
    # named device survives; PCIe override lands in device_spec
    assert c.to_dict()["device"] == "rtx2080ti"
    assert c.device_spec.host_link_total == 10e9
    assert not c.comm_model().global_memory_enabled
    # quantize: floor onto the lattice, clamped to [step, 1.0]
    assert c.quantize(1 / 3) == pytest.approx(0.30)
    assert c.quantize(0.05) == pytest.approx(0.05)   # exact multiple kept
    assert c.quantize(0.001) == pytest.approx(0.05)
    assert c.quantize(7.0) == pytest.approx(1.0)
    with pytest.raises(ValueError):
        ClusterSpec(devices=0)
    with pytest.raises(ValueError):
        ClusterSpec.from_dict({"device": "h100-does-not-exist"})


def test_qos_spec_roundtrip_and_load_model():
    q = QoSSpec(latency_target=0.3, percentile=95.0,
                load=LoadSpec(kind="diurnal", qps=500.0, period=3600.0))
    assert QoSSpec.from_dict(json.loads(json.dumps(q.to_dict()))) == q
    fn = q.load.fn()
    assert fn(0) == pytest.approx(125.0, rel=0.01)          # trough
    assert fn(1800) == pytest.approx(500.0, rel=0.01)       # peak
    assert LoadSpec(qps=42.0).fn()(123.0) == 42.0           # constant
    with pytest.raises(ValueError):
        LoadSpec(kind="sawtooth")
    # latency_target=None inherits the service's own target
    spec = ALL_SPECS["diamond"]
    assert QoSSpec().resolve_target(spec) == spec.qos_target
    assert QoSSpec(latency_target=0.5).resolve_target(spec) == 0.5


# --------------------------------------------------------------------------
# Session end-to-end parity with the hand-wired path
# --------------------------------------------------------------------------

def _hand_wired(graph, n_devices, batch):
    pred = PipelinePredictor.from_graph(graph, RTX_2080TI, seed=0)
    comm = CommModel(RTX_2080TI)
    alloc = CamelotAllocator(graph, pred, RTX_2080TI, n_devices,
                             comm=comm, sa=SA)
    res = alloc.solve_max_load(batch)
    sim = PipelineSimulator(graph, res.allocation, RTX_2080TI, comm,
                            sim=SimConfig(duration=4.0, warmup=0.5, seed=0))
    return res, sim.run(max(res.objective * 0.5, 1.0))


def _facade(spec, n_devices, batch):
    sess = CamelotSession(spec, ClusterSpec(devices=n_devices), batch=batch)
    res = sess.solve(policy="max-peak", sa=SA)
    r = sess.simulate(load=max(res.objective * 0.5, 1.0),
                      sim=SimConfig(duration=4.0, warmup=0.5, seed=0))
    return res, r


@pytest.mark.parametrize("name,n_devices", [("img-to-img", 2),
                                            ("diamond", 4)])
def test_session_parity_with_hand_wired(name, n_devices):
    spec = ALL_SPECS[name]
    hand_res, hand_sim = _hand_wired(spec.build(), n_devices, batch=8)
    face_res, face_sim = _facade(spec, n_devices, batch=8)
    # same allocation, bit for bit
    assert face_res.feasible == hand_res.feasible
    assert face_res.objective == hand_res.objective
    assert [(s.n_instances, s.quota, s.batch)
            for s in face_res.allocation.stages] == \
        [(s.n_instances, s.quota, s.batch)
         for s in hand_res.allocation.stages]
    assert face_res.allocation.placement.per_stage == \
        hand_res.allocation.placement.per_stage
    # same simulated latencies
    assert face_sim.p99 == hand_sim.p99
    assert face_sim.mean_latency == hand_sim.mean_latency
    assert face_sim.completed == hand_sim.completed


def test_session_accepts_graph_and_dict():
    graph = dag_suite()["diamond"]
    spec = ServiceSpec.from_graph(graph)
    from_graph = CamelotSession(graph)
    from_dict = CamelotSession(spec.to_dict())
    assert from_graph.service == spec == from_dict.service


def test_session_fit_from_samples_matches_profile():
    from repro.core.predictor import collect_samples
    spec = ALL_SPECS["img-to-img"]
    sess = CamelotSession(spec, ClusterSpec(devices=2))
    auto = sess.profile().stages
    manual = CamelotSession(spec, ClusterSpec(devices=2)).fit_from_samples(
        [collect_samples(node, RTX_2080TI, seed=i)
         for i, node in enumerate(spec.nodes)]).stages
    for a, m in zip(auto, manual):
        assert a.duration(8, 0.5) == m.duration(8, 0.5)
        assert a.throughput(8, 0.5) == m.throughput(8, 0.5)


# --------------------------------------------------------------------------
# Policy registry
# --------------------------------------------------------------------------

def test_builtin_policies_registered():
    names = available_policies()
    for expect in ("max-peak", "min-resource", "even", "standalone",
                   "laius", "camelot-nc"):
        assert expect in names


def test_unknown_policy_error():
    with pytest.raises(UnknownPolicyError) as ei:
        get_policy("does-not-exist")
    assert "does-not-exist" in str(ei.value)
    assert "max-peak" in str(ei.value)          # lists what IS available
    sess = CamelotSession(ALL_SPECS["img-to-img"])
    with pytest.raises(UnknownPolicyError):
        sess.solve(policy="does-not-exist")


def test_even_policy_matches_baseline():
    spec = ALL_SPECS["img-to-img"]
    sess = CamelotSession(spec, ClusterSpec(devices=2), batch=8)
    res = sess.solve(policy="even")
    base_alloc, base_comm = even_allocation(spec.build(), RTX_2080TI, 2, 8)
    assert [(s.n_instances, s.quota) for s in res.allocation.stages] == \
        [(s.n_instances, s.quota) for s in base_alloc.stages]
    assert res.comm.global_memory_enabled == base_comm.global_memory_enabled
    assert res.policy == "even" and res.mode == "closed-form"
    assert res.feasible and res.objective > 0


def test_min_resource_policy_load_resolution():
    spec = ALL_SPECS["img-to-img"]
    sess = CamelotSession(spec, ClusterSpec(devices=2), batch=8)
    with pytest.raises(ValueError):         # no load target anywhere
        sess.solve(policy="min-resource", sa=SA)
    # QoSSpec.load supplies the target
    sess2 = CamelotSession(spec, ClusterSpec(devices=2),
                           QoSSpec(load=LoadSpec(qps=50.0)), batch=8)
    res = sess2.solve(policy="min-resource", sa=SA)
    assert res.feasible and res.policy == "min-resource"
    assert res.allocation.total_quota() < 2.0   # right-sized below peak


def test_register_custom_policy_dispatch():
    class FixedPolicy:
        name = "fixed-even"

        def solve(self, spec, predictor, cluster, qos, batch=8):
            alloc, comm = even_allocation(spec.build(qos),
                                          cluster.device_spec,
                                          cluster.devices, batch)
            from repro.core.allocator import SolveResult
            res = SolveResult(allocation=alloc, objective=1.0,
                              feasible=True, solve_time=0.0, iterations=0)
            res.comm, res.policy = comm, self.name
            return res

    try:
        register_policy(FixedPolicy())      # class instances register
        assert "fixed-even" in available_policies()
        sess = CamelotSession(ALL_SPECS["img-to-img"],
                              ClusterSpec(devices=2))
        res = sess.solve(policy="fixed-even")
        assert res.policy == "fixed-even" and res.feasible
        # duplicate names are rejected unless overwrite is explicit
        with pytest.raises(ValueError):
            register_policy(FixedPolicy())
        register_policy(FixedPolicy(), overwrite=True)
    finally:
        _REGISTRY.pop("fixed-even", None)


def test_solver_policies_reject_off_lattice_quota_step():
    """The SA solver's decision lattice is the module-wide QUOTA_STEP grid;
    a cluster declaring another quota_step must fail loudly (quantize()
    still honours it for demo allocations)."""
    sess = CamelotSession(ALL_SPECS["img-to-img"],
                          ClusterSpec(devices=2, quota_step=0.1))
    with pytest.raises(ValueError, match="QUOTA_STEP"):
        sess.solve(policy="max-peak", sa=SA)
    assert ClusterSpec(quota_step=0.1).quantize(0.17) == pytest.approx(0.1)


def test_session_runtime_inherits_cluster_comm():
    """The online loop must price communication exactly as the offline
    solves did: ClusterSpec.comm_model() flows into CamelotRuntime."""
    spec = ALL_SPECS["img-to-img"]
    cluster = ClusterSpec(devices=2, global_memory=False, ici_bandwidth=9e9)
    sess = CamelotSession(spec, cluster, batch=8)
    rt = sess.runtime(sa=SA)
    assert not rt.comm.global_memory_enabled
    assert rt.comm.ici_bandwidth == 9e9
    assert rt.allocator.comm is rt.comm


def test_policy_instance_passthrough():
    pol = MaxPeakPolicy(sa=SA, name="local-max")   # NOT registered
    sess = CamelotSession(ALL_SPECS["img-to-img"], ClusterSpec(devices=2),
                          batch=8)
    res = sess.solve(policy=pol)
    assert res.policy == "local-max" and res.feasible
    assert "local-max" not in available_policies()


# --------------------------------------------------------------------------
# Session serving (live engine wiring)
# --------------------------------------------------------------------------

def test_session_serve_runs_solved_allocation_live():
    spec = ALL_SPECS["text-to-text"]
    sess = CamelotSession(spec, ClusterSpec(devices=2), batch=4)
    res = sess.solve(policy="max-peak", sa=SA)
    eng = sess.serve(result=res)
    assert len(eng.stages) == spec.n_nodes
    n_inst = [len(p) for p in res.allocation.placement.per_stage]
    assert [len(p) for p in eng.alloc.placement.per_stage] == n_inst
    stats = eng.run_trace(sess.make_trace(6, qps=30.0, seed=1))
    assert stats.summary()["completed"] == 6
