"""Measurement-plane contracts: the fast simulator path is bit-identical
to the legacy path, QoS early-abort never flips a verdict, and the
lattice peak search is path- and parallelism-independent."""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.camelot import ClusterSpec, MultiServiceSession
from repro.core import RTX_2080TI
from repro.core.qos import abort_threshold
from repro.sim import (MIN_COMPLETED, SimConfig, camelot_suite, dag_suite,
                       even_allocation, find_joint_peak, multitenant_suite)
from repro.sim.simulator import (MultiTenantSimulator, PipelineSimulator,
                                 bracketed_peak_search)

CFG = SimConfig(duration=4.0, warmup=1.0, seed=0)
FAST = SimConfig(duration=4.0, warmup=1.0, seed=0, fast=True)
SLOW = SimConfig(duration=4.0, warmup=1.0, seed=0, fast=False)
ABORT = SimConfig(duration=4.0, warmup=1.0, seed=0, fast=True,
                  abort_over_target=True)


def _assert_bit_identical(a, b):
    assert a.p99 == b.p99
    assert a.mean_latency == b.mean_latency
    assert a.completed == b.completed
    assert a.events == b.events
    assert list(a.qos.latencies) == list(b.qos.latencies)
    assert a.device_busy == b.device_busy


def _multi_setup(name):
    tenants = multitenant_suite()[name]
    devices = {"chain+diamond": 3, "two-chains": 3, "3-tenant-mixed": 4}[name]
    sess = MultiServiceSession(tenants, ClusterSpec(devices=devices),
                               batch=8, name=name)
    allocs = [even_allocation(t.graph, RTX_2080TI, devices, batch=8)[0]
              for t in tenants]
    return sess, allocs, sess.cluster.comm_model()


# ---- fast-vs-legacy bit parity --------------------------------------------

@pytest.mark.parametrize("name", list(camelot_suite()))
@pytest.mark.parametrize("qps", [20.0, 150.0])
def test_chain_parity(name, qps):
    graph = camelot_suite()[name]
    alloc, comm = even_allocation(graph, RTX_2080TI, 2, batch=8)
    rl = PipelineSimulator(graph, alloc, RTX_2080TI, comm, SLOW).run(qps)
    rf = PipelineSimulator(graph, alloc, RTX_2080TI, comm, FAST).run(qps)
    _assert_bit_identical(rl, rf)


@pytest.mark.parametrize("name", list(dag_suite()))
def test_dag_parity(name):
    graph = dag_suite()[name]
    alloc, comm = even_allocation(graph, RTX_2080TI, 2, batch=8)
    for qps in (15.0, 120.0):
        rl = PipelineSimulator(graph, alloc, RTX_2080TI, comm, SLOW).run(qps)
        rf = PipelineSimulator(graph, alloc, RTX_2080TI, comm, FAST).run(qps)
        _assert_bit_identical(rl, rf)


@pytest.mark.parametrize("name", list(multitenant_suite()))
def test_multitenant_parity(name):
    sess, allocs, comm = _multi_setup(name)
    loads = [80.0 * w for w in sess.weights]
    rl = MultiTenantSimulator(sess.tenant_set, allocs,
                              sess.cluster.device_spec, comm,
                              sim=SLOW).run(loads)
    rf = MultiTenantSimulator(sess.tenant_set, allocs,
                              sess.cluster.device_spec, comm,
                              sim=FAST).run(loads)
    assert rl.events == rf.events
    assert rl.device_busy == rf.device_busy
    for a, b in zip(rl.per_tenant, rf.per_tenant):
        _assert_bit_identical(a, b)


def test_shared_simulator_rerun_parity():
    """A shared (table-warm) simulator reproduces a fresh one exactly."""
    sess, allocs, comm = _multi_setup("chain+diamond")
    shared = MultiTenantSimulator(sess.tenant_set, allocs,
                                  sess.cluster.device_spec, comm, sim=FAST)
    for loads in ([30.0, 30.0], [120.0, 120.0], [30.0, 30.0]):
        fresh = MultiTenantSimulator(sess.tenant_set, allocs,
                                     sess.cluster.device_spec, comm,
                                     sim=FAST)
        a, b = shared.run(loads), fresh.run(loads)
        for x, y in zip(a.per_tenant, b.per_tenant):
            _assert_bit_identical(x, y)


# ---- per-tenant result ownership (the aliasing fix) ------------------------

def test_per_tenant_results_not_aliased():
    sess, allocs, comm = _multi_setup("two-chains")
    r = MultiTenantSimulator(sess.tenant_set, allocs,
                             sess.cluster.device_spec, comm,
                             sim=FAST).run([60.0, 60.0])
    busies = [t.device_busy for t in r.per_tenant]
    assert all(b is not r.device_busy for b in busies)
    assert busies[0] is not busies[1]
    for dev, total in r.device_busy.items():
        per = sum(b.get(dev, 0.0) for b in busies)
        assert math.isclose(per, total, rel_tol=1e-9)
    assert sum(t.events for t in r.per_tenant) == r.events
    assert all(t.events < r.events for t in r.per_tenant)


# ---- unified feasibility predicate ----------------------------------------

def test_meets_qos_min_completed():
    graph = camelot_suite()["img-to-img"]
    alloc, comm = even_allocation(graph, RTX_2080TI, 2, batch=8)
    r = PipelineSimulator(graph, alloc, RTX_2080TI, comm, FAST).run(30.0)
    assert r.qos.count() >= MIN_COMPLETED
    assert r.meets_qos(graph.qos_target) == (r.p99 <= graph.qos_target)
    # starved run: too few samples can never pass, whatever its p99
    r2 = PipelineSimulator(graph, alloc, RTX_2080TI, comm,
                           SimConfig(duration=1.2, warmup=1.0, seed=0,
                                     fast=True)).run(1.0)
    if r2.qos.count() < MIN_COMPLETED:
        assert not r2.meets_qos(graph.qos_target)


# ---- the exact abort bound ------------------------------------------------

@settings(max_examples=40)
@given(n=st.integers(1, 5000), pct=st.sampled_from([90.0, 95.0, 99.0]))
def test_abort_threshold_bound(n, pct):
    """thr(n) is the MINIMAL over-target count that forces the numpy
    linear-interpolation percentile over the target, and is monotone."""
    t = 1.0
    thr = abort_threshold(n, pct)
    assert 1 <= thr <= n
    # soundness: thr barely-over samples force the percentile over the
    # target even when every other sample sits exactly AT the target
    worst = np.array([t] * (n - thr) + [t + 1e-6] * thr)
    assert np.percentile(worst, pct) > t
    # minimality: with one fewer over-target sample a compliant run exists
    ok = np.array([0.0] * (n - thr + 1) + [t + 1e-9] * (thr - 1))
    assert np.percentile(ok, pct) <= t
    assert abort_threshold(n + 1, pct) >= thr


@settings(max_examples=8)
@given(mult=st.floats(0.4, 3.0))
def test_abort_never_flips_verdict(mult):
    sess, allocs, comm = _multi_setup("chain+diamond")
    loads = [170.0 * mult * w for w in sess.weights]
    full = MultiTenantSimulator(sess.tenant_set, allocs,
                                sess.cluster.device_spec, comm,
                                sim=FAST).run(loads)
    ab = MultiTenantSimulator(sess.tenant_set, allocs,
                              sess.cluster.device_spec, comm,
                              sim=ABORT).run(loads)
    assert ab.meets_qos(sess.qos_targets) == full.meets_qos(sess.qos_targets)
    if ab.aborted:
        assert not ab.meets_qos(sess.qos_targets)
    else:   # no abort fired: the runs must be bit-identical
        for a, b in zip(full.per_tenant, ab.per_tenant):
            _assert_bit_identical(a, b)


# ---- lattice peak search: path and parallelism independence ---------------

def _fake_probe(true_peak):
    return lambda load: {"load": load, "feasible": load <= true_peak}


def test_lattice_search_path_independent():
    """Blind, seeded-accurate, and seeded-overshooting searches all land
    on the same lattice point — the boundary belongs to the system, not
    to the search path."""
    probe = _fake_probe(460.0)
    meets = lambda r: r["feasible"]
    blind, _ = bracketed_peak_search(probe, meets, lo=2.0, hi=4096.0)
    for seed in (455.0, 470.0, 800.0, 40.0):
        peak, r = bracketed_peak_search(probe, meets, lo=2.0, hi=4096.0,
                                        seed_load=seed)
        assert peak == blind
        assert r["load"] == peak and r["feasible"]
    assert 460.0 / 1.03 < blind <= 460.0


def test_lattice_search_parallel_identity():
    probe = _fake_probe(123.0)
    meets = lambda r: r["feasible"]
    seq = bracketed_peak_search(probe, meets, lo=2.0, hi=4096.0,
                                seed_load=120.0)
    for k in (2, 4):
        par = bracketed_peak_search(probe, meets, lo=2.0, hi=4096.0,
                                    seed_load=120.0, parallel=k)
        assert par == seq


def test_lattice_search_lo_fails():
    probe = _fake_probe(0.5)
    peak, r = bracketed_peak_search(probe, lambda r: r["feasible"],
                                    lo=2.0, hi=4096.0)
    assert peak == 0.0 and not r["feasible"]


def test_lattice_search_budget_exact():
    calls = []
    probe = lambda load: (calls.append(load), load)[1]
    meets = lambda r: r <= 300.0
    bracketed_peak_search(probe, meets, lo=2.0, hi=4096.0, max_iter=3)
    # lo is probed outside the budget; exactly max_iter refinement probes
    assert len(calls) == 1 + 3


def test_sim_search_parallel_and_abort_identity():
    """On the real simulator: sequential/parallel and abort-on/off agree
    on the peak and return bit-identical results at that peak."""
    sess, allocs, comm = _multi_setup("chain+diamond")
    mk = lambda: MultiTenantSimulator(sess.tenant_set, allocs,
                                      sess.cluster.device_spec, comm,
                                      sim=FAST)
    base = find_joint_peak(mk, sess.qos_targets, weights=sess.weights,
                           lo=2.0, hi=2048.0)
    for kw in ({"parallel": 4}, {"abort": True},
               {"parallel": 2, "abort": True},
               {"seed_load": base[0], "abort": True}):
        lam, r = find_joint_peak(mk, sess.qos_targets, weights=sess.weights,
                                 lo=2.0, hi=2048.0, **kw)
        assert lam == base[0]
        for a, b in zip(base[1].per_tenant, r.per_tenant):
            _assert_bit_identical(a, b)
