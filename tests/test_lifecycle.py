"""Tenant lifecycle control plane (core.lifecycle + facade surface).

Contracts pinned here:

  1. **Validation at construction**: bad Tenant/TenantSpec fields (weight,
     QoS target, floors/caps, utility) raise clear ValueErrors.
  2. **No-lifecycle bit-parity**: tenants with the lifecycle knobs at
     their defaults lower to ``iso_bounds() is None`` /
     ``utility_codes() is None`` and solve bit-identically to the
     pre-lifecycle path (priority alone never changes a solve).
  3. **Isolation floors/caps are solver constraints in every mode**:
     scalar / vectorized / incremental / jax (and the hierarchical
     decomposition) all return allocations whose per-tenant total quota
     respects the declared bounds, and incremental stays bit-identical
     to the dense evaluator with the constraint active.
  4. **Admission control**: accept/deny is deterministic, every denial
     quote is certified by an independent feasible re-solve at the
     quoted point, and admissions preserve every incumbent verdict.
  5. **Preemption** sheds in strict ascending ``(priority, weight)``
     order, recorded as ``reason="preempted"``.
  6. **Mutation API** round-trips through session save/load.
  7. **Chaos churn** (property test over the hypothesis fallback): any
     seeded churn script replays without breaking the invariants.
"""
import dataclasses
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.camelot import (ClusterSpec, MultiServiceSession, QoSSpec,
                           SAConfig, ServiceSpec, SolverSpec, TenantSpec)
from repro.core import (HierarchicalSolver, LifecycleManager,
                        MultiTenantAllocator, PipelinePredictor, PodConfig,
                        RTX_2080TI)
from repro.core.runtime import MultiTenantRuntime, RuntimeConfig
from repro.core.types import QUOTA_STEP, Pipeline, Tenant, TenantSet
from repro.sim.workloads import (artifact_stage, camelot_suite, churn_suite,
                                 churn_tenant, churn_trace)

SA = SAConfig(iterations=500, seed=0)
DEV = RTX_2080TI


def _chain(name, kinds, qos=0.3, **kw):
    return Tenant(name, Pipeline(
        name, [artifact_stage(k, l) for k, l in kinds], qos_target=qos),
        **kw)


def _pred(tenants, seed=0):
    return PipelinePredictor.from_graph(
        TenantSet(tenants).union_graph, DEV, seed=seed)


# --------------------------------------------------------------------------
# 1. validation
# --------------------------------------------------------------------------

def test_tenant_validation_errors():
    g = Pipeline("p", [artifact_stage("c", 1)], qos_target=0.3)
    with pytest.raises(ValueError, match="weight"):
        Tenant("t", g, weight=0.0)
    with pytest.raises(ValueError, match="weight"):
        Tenant("t", g, weight=-2.0)
    bad = Pipeline("p", [artifact_stage("c", 1)], qos_target=0.0)
    with pytest.raises(ValueError, match="QoS"):
        Tenant("t", bad)
    with pytest.raises(ValueError, match="required_load"):
        Tenant("t", g, required_load=0.0)
    with pytest.raises(ValueError, match="quota_floor"):
        Tenant("t", g, quota_floor=-0.1)
    with pytest.raises(ValueError, match="quota_cap"):
        Tenant("t", g, quota_floor=1.0, quota_cap=0.5)
    with pytest.raises(ValueError, match="quota_cap"):
        Tenant("t", g, quota_cap=QUOTA_STEP / 2)
    with pytest.raises(ValueError, match="utility"):
        Tenant("t", g, utility="cubic")
    # valid lifecycle knobs construct fine
    t = Tenant("t", g, priority=3, quota_floor=0.5, quota_cap=2.0,
               utility="log")
    assert t.isolated


def test_tenant_spec_validation_and_roundtrip():
    svc = ServiceSpec.from_graph(camelot_suite()["img-to-img"])
    with pytest.raises(ValueError, match="quota_floor"):
        TenantSpec(svc, quota_floor=-1.0)
    with pytest.raises(ValueError, match="quota_cap"):
        TenantSpec(svc, quota_floor=2.0, quota_cap=1.0)
    with pytest.raises(ValueError, match="utility"):
        TenantSpec(svc, utility="exp")
    spec = TenantSpec(svc, QoSSpec(), weight=1.5, priority=2,
                      quota_floor=0.5, quota_cap=2.5, utility="sqrt")
    back = TenantSpec.from_dict(spec.to_dict())
    assert back == spec
    t = back.build()
    assert (t.priority, t.quota_floor, t.quota_cap, t.utility) == \
        (2, 0.5, 2.5, "sqrt")


# --------------------------------------------------------------------------
# 2. no-lifecycle bit-parity
# --------------------------------------------------------------------------

def test_plain_tenants_lower_to_no_constraints():
    ts = TenantSet(churn_suite()[:2])     # no floors/caps on these two
    assert ts.iso_bounds() is None
    assert ts.utility_codes() is None


def test_priority_alone_is_solve_invariant():
    base = [_chain("a", [("c", 1), ("m", 1)]),
            _chain("b", [("p", 1), ("c", 2)])]
    tiered = [dataclasses.replace(base[0], priority=2),
              dataclasses.replace(base[1], priority=1)]
    pred = _pred(base)
    r0 = MultiTenantAllocator(TenantSet(base), pred, DEV, 4,
                              sa=SA).solve_max_load(8)
    r1 = MultiTenantAllocator(TenantSet(tiered), pred, DEV, 4,
                              sa=SA).solve_max_load(8)
    assert r0.objective == r1.objective
    assert [(s.n_instances, s.quota) for s in r0.allocation.stages] == \
        [(s.n_instances, s.quota) for s in r1.allocation.stages]


# --------------------------------------------------------------------------
# 3. isolation floors/caps across every solver mode
# --------------------------------------------------------------------------

def _iso_tenants():
    return [_chain("floor", [("c", 1), ("m", 1)], qos=0.35,
                   quota_floor=1.0),
            _chain("cap", [("p", 1), ("c", 1)], qos=0.35,
                   quota_cap=0.8),
            _chain("free", [("m", 1), ("p", 1)], qos=0.35)]


def _tenant_quotas(ts, alloc):
    out = []
    for t, off in zip(ts.tenants, ts.offsets):
        n = t.graph.n_nodes
        out.append(sum(s.n_instances * s.quota
                       for s in alloc.stages[off:off + n]))
    return out


@pytest.mark.parametrize("mode", ["scalar", "vectorized", "incremental",
                                  "jax"])
def test_iso_bounds_enforced_every_mode(mode):
    tenants = _iso_tenants()
    ts = TenantSet(tenants)
    pred = _pred(tenants)
    sa = dataclasses.replace(SA, mode=mode)
    res = MultiTenantAllocator(ts, pred, DEV, 4, sa=sa).solve_max_load(8)
    assert res.feasible
    tq = _tenant_quotas(ts, res.allocation)
    assert tq[0] >= 1.0 - 1e-9, tq
    assert tq[1] <= 0.8 + 1e-9, tq


def test_iso_bounds_incremental_bit_identical_to_dense():
    tenants = _iso_tenants()
    ts = TenantSet(tenants)
    pred = _pred(tenants)
    r_vec = MultiTenantAllocator(
        ts, pred, DEV, 4,
        sa=dataclasses.replace(SA, mode="vectorized")).solve_max_load(8)
    r_inc = MultiTenantAllocator(
        ts, pred, DEV, 4,
        sa=dataclasses.replace(SA, mode="incremental")).solve_max_load(8)
    assert r_vec.objective == r_inc.objective
    assert [(s.n_instances, s.quota) for s in r_vec.allocation.stages] == \
        [(s.n_instances, s.quota) for s in r_inc.allocation.stages]


def test_iso_bounds_enforced_hierarchical():
    tenants = _iso_tenants()
    ts = TenantSet(tenants)
    pred = _pred(tenants)
    res = HierarchicalSolver(ts, pred, DEV, 4, sa=SA,
                             pods=PodConfig(pod_size=2)).solve_max_load(8)
    assert res.feasible
    tq = _tenant_quotas(ts, res.allocation)
    assert tq[0] >= 1.0 - 1e-9, tq
    assert tq[1] <= 0.8 + 1e-9, tq


def test_min_resource_ladder_respects_floor_sum():
    # floors sum to 3 => no rung below 3 devices can be feasible
    tenants = [_chain("f1", [("c", 1)], quota_floor=1.5),
               _chain("f2", [("m", 1)], quota_floor=1.5)]
    ts = TenantSet(tenants)
    pred = _pred(tenants)
    res = MultiTenantAllocator(ts, pred, DEV, 6, sa=SA)\
        .solve_min_resource(8, [5.0, 5.0])
    assert res.feasible
    used = res.allocation.placement.devices_used()
    assert len(used) >= 3
    tq = _tenant_quotas(ts, res.allocation)
    assert all(q >= 1.5 - 1e-9 for q in tq), tq


def test_infeasible_iso_bounds_reported_infeasible():
    # cap below what the QoS target needs => infeasible, not violated
    tenants = [_chain("starved", [("c", 3), ("c", 3)], qos=0.05,
                      quota_cap=QUOTA_STEP)]
    pred = _pred(tenants)
    res = MultiTenantAllocator(TenantSet(tenants), pred, DEV, 2,
                               sa=SA).solve_max_load(8)
    assert not res.feasible


# --------------------------------------------------------------------------
# utility curves
# --------------------------------------------------------------------------

def test_utility_curves_shape_objective():
    base = [_chain("a", [("c", 1), ("m", 1)]),
            _chain("b", [("p", 1), ("c", 2)])]
    pred = _pred(base)
    lin = MultiTenantAllocator(TenantSet(base), pred, DEV, 4,
                               sa=SA).solve_max_load(8)
    logs = [dataclasses.replace(t, utility="log") for t in base]
    res = MultiTenantAllocator(TenantSet(logs), pred, DEV, 4,
                               sa=SA).solve_max_load(8)
    assert lin.feasible and res.feasible
    # objective is now in utility units (log1p of the linear value)
    assert res.objective == pytest.approx(math.log1p(lin.objective),
                                          rel=0.05)
    assert res.load is None             # utility units are not qps
    assert lin.load == lin.objective


def test_utility_suspended_for_min_resource():
    base = [_chain("a", [("c", 1), ("m", 1)], utility="sqrt"),
            _chain("b", [("p", 1), ("c", 2)])]
    pred = _pred(base)
    res = MultiTenantAllocator(TenantSet(base), pred, DEV, 4, sa=SA)\
        .solve_min_resource(8, [20.0, 20.0])
    assert res.feasible
    # min-resource objective stays in quota units (negative total quota)
    assert res.objective == pytest.approx(
        -res.allocation.total_quota(), abs=1e-9)


# --------------------------------------------------------------------------
# 4. admission control
# --------------------------------------------------------------------------

def _manager(n_devices=6, sa=SA, tenants=None):
    tenants = tenants if tenants is not None else churn_suite()
    ts = TenantSet(tenants)
    pred = PipelinePredictor.from_graph(ts.union_graph, DEV, seed=0)
    return LifecycleManager(ts, pred, DEV, n_devices, 8, sa=sa)


def test_admission_accept_preserves_incumbent_verdicts():
    mgr = _manager()
    before = set(mgr.tenant_names)
    t = churn_tenant(0, np.random.default_rng(1))
    dec = mgr.admit(1.0, t)
    assert dec.admitted and dec.result.feasible
    verdicts = mgr.qos_verdicts()
    assert set(verdicts) == before | {t.name}
    assert all(verdicts.values()), verdicts


def test_admission_is_deterministic():
    t = churn_tenant(0, np.random.default_rng(1))
    d1 = _manager().admit(1.0, t)
    d2 = _manager().admit(1.0, t)
    assert d1.admitted == d2.admitted
    assert d1.result.objective == d2.result.objective
    assert [(s.n_instances, s.quota) for s in d1.result.allocation.stages] \
        == [(s.n_instances, s.quota) for s in d2.result.allocation.stages]


def test_denial_quotes_are_certified():
    mgr = _manager(n_devices=4)
    big = dataclasses.replace(churn_tenant(0, np.random.default_rng(2)),
                              required_load=5000.0, quota_floor=0.0,
                              quota_cap=None)
    dec = mgr.admit(1.0, big)
    assert not dec.admitted
    assert dec.quotes, "denial must carry at least one quote"
    # re-certify each quote with an INDEPENDENT cold solve at the
    # quoted operating point
    for q in dec.quotes:
        assert q.certified
        cand = list(mgr.tenants.tenants)
        loads = mgr._required_loads(cand) + [big.required_load]
        n_dev = mgr.n_devices
        newcomer = big
        if q.kind == "reduce_load":
            loads[-1] = q.load
        elif q.kind == "relax_qos":
            g = big.graph
            newcomer = dataclasses.replace(big, graph=Pipeline(
                g.name, g.nodes, qos_target=q.qos_target))
        else:
            n_dev += q.extra_devices
        cand = cand + [newcomer]
        res = MultiTenantAllocator(
            TenantSet(cand),
            PipelinePredictor.from_graph(TenantSet(cand).union_graph, DEV,
                                         seed=0),
            DEV, n_dev, sa=SA).solve_min_resource(8, loads)
        assert res.feasible, q


def test_admission_warm_not_worse_than_cold():
    t = churn_tenant(0, np.random.default_rng(1))
    warm = _manager().admit(1.0, t, warm=True)
    cold = _manager().admit(1.0, t, warm=False)
    assert warm.admitted and cold.admitted
    assert warm.result.objective >= cold.result.objective - 1e-9


def test_duplicate_admission_rejected():
    mgr = _manager()
    with pytest.raises(ValueError, match="already admitted"):
        mgr.admit(0.0, churn_suite()[0])


# --------------------------------------------------------------------------
# 5. preemption
# --------------------------------------------------------------------------

def test_preemption_sheds_in_strict_priority_order():
    tenants = [_chain("gold", [("c", 1), ("m", 1)], priority=2,
                      required_load=20.0),
               _chain("bronze", [("p", 1), ("c", 1)], priority=0,
                      required_load=20.0),
               _chain("silver", [("m", 1), ("p", 1)], priority=1,
                      required_load=20.0)]
    mgr = _manager(n_devices=3, tenants=tenants)
    # a spike no 3-device pool can hold for everyone
    mgr.preempt(1.0, targets=[4000.0, 4000.0, 4000.0])
    ev = mgr.runtime.history[-1]
    assert ev.reason == "preempted"
    assert list(ev.shed)[:2] == ["bronze", "silver"] or \
        list(ev.shed) == ["bronze"], ev.shed
    # lifecycle log mirrors the runtime event
    assert mgr.events[-1].op == "preempt"
    assert mgr.events[-1].detail["shed"] == list(ev.shed)


def test_preempt_feasible_spike_sheds_nothing():
    mgr = _manager()
    mgr.preempt(1.0, targets=[10.0, 10.0, 10.0])
    ev = mgr.runtime.history[-1]
    assert ev.reason == "load" and ev.shed == ()


def test_runtime_history_is_bounded():
    tenants = churn_suite()[:1]
    ts = TenantSet(tenants)
    pred = PipelinePredictor.from_graph(ts.union_graph, DEV, seed=0)
    rt = MultiTenantRuntime(ts, pred, DEV, 2, 8,
                            rt=RuntimeConfig(history_limit=5), sa=SA)
    for k in range(9):
        rt.observe([10.0])
        rt.reallocate(float(k))
    assert len(rt.history) == 5
    assert rt.history[0].time == 4.0    # oldest events evicted


# --------------------------------------------------------------------------
# 6. mutation API + persistence
# --------------------------------------------------------------------------

def test_mutations_roundtrip_through_save_load(tmp_path):
    sess = MultiServiceSession(churn_suite(), ClusterSpec(devices=6),
                               solver=SolverSpec(iterations=500, seed=0))
    sess.profile()
    t = churn_tenant(0, np.random.default_rng(1))
    dec = sess.admit(t, now=1.0)
    assert dec.admitted
    assert sess.scale_tenant("base-lo", required_load=25.0,
                             now=2.0).feasible
    assert sess.retarget_qos("base-mid", 0.5, now=3.0).feasible
    path = tmp_path / "sess.json"
    sess.save(str(path))
    back = MultiServiceSession.load(str(path))
    assert [s.name for s in back.spec.tenants] == \
        [s.name for s in sess.spec.tenants]
    assert back.spec.tenants[0].qos.load.qps == 25.0
    assert back.spec.tenants[1].qos.latency_target == 0.5
    # admitted tenant's lifecycle knobs survive the round-trip
    mine = back.spec.tenants[-1]
    assert (mine.priority, mine.quota_floor, mine.quota_cap,
            mine.utility) == (t.priority, t.quota_floor, t.quota_cap,
                              t.utility)
    # the lifecycle event log is restored verbatim
    back.profile()
    ops = [(e.op, e.tenant) for e in back.lifecycle().events]
    assert ops == [("admit", t.name), ("scale", "base-lo"),
                   ("retarget", "base-mid")]
    # eviction shrinks the spec and the predictor namespace together
    assert sess.evict(t.name, now=4.0).feasible
    assert t.name not in [s.name for s in sess.spec.tenants]
    assert len(sess.predictor.stages) == sess.tenant_set.n_nodes


# --------------------------------------------------------------------------
# 7. chaos churn (property test)
# --------------------------------------------------------------------------

@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 6))
def test_churn_replay_invariants(seed):
    fast = dataclasses.replace(SA, iterations=300)
    mgr = _manager(n_devices=6, sa=fast)
    for ev in churn_trace(n_events=6, seed=seed):
        if ev["op"] == "admit":
            dec = mgr.admit(ev["t"], ev["tenant"],
                            quote_kinds=("reduce_load",))
            if dec.admitted:
                assert all(mgr.qos_verdicts().values())
            else:
                assert all(q.certified for q in dec.quotes)
        elif ev["op"] == "remove":
            if ev["name"] in mgr.tenant_names:
                mgr.remove(ev["t"], ev["name"])
        elif ev["op"] == "scale":
            if ev["name"] in mgr.tenant_names:
                mgr.scale_tenant(ev["t"], ev["name"],
                                 required_load=max(
                                     1.0, 30.0 * ev["factor"]))
        else:
            spike = [ev["factor"] * 30.0] * len(mgr.tenant_names)
            mgr.preempt(ev["t"], targets=spike)
        # invariants after every step
        names = mgr.tenant_names
        assert len(set(names)) == len(names)
        assert len(mgr.predictor.stages) == mgr.tenants.n_nodes
        assert mgr.runtime.current is not None
    assert len(mgr.events) > 0
