"""Fault-tolerant serving plane: seeded failure injection, no-fault
bit-parity, health-monitored detection, masked re-solve with weight-order
degradation, engine-level retry/deadline/deadlock robustness, and
crash-restart recovery with no cold solve."""
import math
import threading
import time
import types

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to deterministic example sweeps
    from _hypothesis_fallback import given, settings, st

from repro.camelot import ClusterSpec, MultiServiceSession, SAConfig
from repro.core import (RTX_2080TI, BatchingPolicy, CamelotAllocator,
                        DeviceFailure, ExecCore, FaultSpec,
                        MultiTenantAllocator, PipelinePredictor, Straggle,
                        TransientErrors, default_allocation)
from repro.core.allocator import _remap_placement
from repro.core.hierarchy import HierarchicalSolver
from repro.core.runtime import (HealthMonitor, MultiTenantRuntime,
                                ReallocationEvent, RuntimeConfig)
from repro.core.types import (Allocation, Placement, StageAlloc, Tenant,
                              TenantSet)
from repro.serving import MultiTenantEngine, PipelineEngine, Query
from repro.sim import MultiTenantSimulator, SimConfig
from repro.sim.workloads import camelot_suite, dag_suite

SA = SAConfig(iterations=400, seed=0)
SIM = SimConfig(duration=3.0, warmup=0.5, seed=0)


# --------------------------------------------------------------------------
# FaultSpec round-trip + activity predicate
# --------------------------------------------------------------------------

def test_faultspec_roundtrip():
    fs = FaultSpec(
        device_failures=(DeviceFailure(time=1.5, device=2),),
        straggles=(Straggle(time=0.5, device=1, factor=4.0, until=2.0),
                   Straggle(time=1.0, device=0)),       # open-ended
        transient=TransientErrors(rate=0.1, start=0.5, until=2.5),
        seed=7, max_retries=3)
    back = FaultSpec.from_dict(fs.to_dict())
    assert back == fs
    assert math.isinf(back.straggles[1].until)


def test_faultspec_active_predicate():
    assert not FaultSpec().active()
    assert not FaultSpec(transient=TransientErrors(rate=0.0)).active()
    assert FaultSpec(device_failures=(DeviceFailure(1.0, 0),)).active()
    assert FaultSpec(straggles=(Straggle(1.0, 0),)).active()
    assert FaultSpec(transient=TransientErrors(rate=0.2)).active()


# --------------------------------------------------------------------------
# simulator fault injection (shared joint scenario)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def joint():
    """chain+diamond on 3 shared devices: solved once, simulated many."""
    sess = MultiServiceSession(
        [Tenant("img-to-img", camelot_suite()["img-to-img"]),
         Tenant("diamond", dag_suite()["diamond"])],
        ClusterSpec(devices=3), batch=8, name="fault-fixture")
    res = sess.solve(policy="max-peak", sa=SA)
    assert res.feasible
    loads = [0.3 * res.objective * w for w in sess.weights]
    return sess, res, loads


def _fingerprint(result):
    return [(r.p99, r.mean_latency, r.completed, r.failed, r.retries)
            for r in result.per_tenant]


@pytest.mark.parametrize("fast", [True, False])
def test_nofault_bit_parity(joint, fast):
    """faults=None, an inactive FaultSpec, and the pre-fault call shape
    are bit-identical — on the fast AND the legacy plane."""
    sess, res, loads = joint
    cfg = SimConfig(duration=SIM.duration, warmup=SIM.warmup, fast=fast)
    base = sess.simulate(loads, sim=cfg)
    empty = sess.simulate(loads, sim=cfg, faults=FaultSpec())
    inert = sess.simulate(loads, sim=cfg, faults=FaultSpec(
        transient=TransientErrors(rate=0.0), seed=99))
    assert _fingerprint(base) == _fingerprint(empty) == _fingerprint(inert)
    assert all(r.failed == 0 and r.retries == 0 for r in base.per_tenant)


def test_device_death_freezes_heartbeat_and_fails_queries(joint):
    sess, res, loads = joint
    t_fail = 1.5
    quota = {}
    for placed in res.allocation.placement.per_stage:
        for d, q in placed:
            quota[d] = quota.get(d, 0.0) + q
    victim = max(quota, key=quota.get)
    r = sess.simulate(loads, sim=SIM, faults=FaultSpec(
        device_failures=(DeviceFailure(time=t_fail, device=victim),)))
    # the victim's heartbeat froze at (or before) the kill; survivors kept
    # completing work until the end of the timeline
    assert r.heartbeats[victim] <= t_fail
    assert any(t > t_fail for d, t in r.heartbeats.items() if d != victim)
    assert sum(t.failed for t in r.per_tenant) > 0


def test_straggle_inflates_then_recovers(joint):
    sess, res, loads = joint
    base = sess.simulate(loads, sim=SIM)
    slow = sess.simulate(loads, sim=SIM, faults=FaultSpec(
        straggles=(Straggle(time=0.0, device=0, factor=8.0),)))
    eased = sess.simulate(loads, sim=SIM, faults=FaultSpec(
        straggles=(Straggle(time=0.0, device=0, factor=8.0, until=0.2),)))
    assert max(r.p99 for r in slow.per_tenant) > \
        max(r.p99 for r in base.per_tenant)
    # a straggle that lifts early hurts less than one that never does
    assert max(r.p99 for r in eased.per_tenant) < \
        max(r.p99 for r in slow.per_tenant)


def test_transient_errors_retry_then_fail(joint):
    sess, res, loads = joint
    trans = TransientErrors(rate=0.25, start=0.5, until=2.0)
    with_retry = sess.simulate(loads, sim=SIM, faults=FaultSpec(
        transient=trans, seed=3, max_retries=2))
    no_retry = sess.simulate(loads, sim=SIM, faults=FaultSpec(
        transient=trans, seed=3, max_retries=0))
    assert sum(r.retries for r in with_retry.per_tenant) > 0
    assert sum(r.failed for r in no_retry.per_tenant) > \
        sum(r.failed for r in with_retry.per_tenant)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), victim=st.integers(0, 2),
       t_fail=st.floats(0.5, 2.5))
def test_chaos_seeded_faults_are_deterministic(joint, seed, victim, t_fail):
    """Same seeded FaultSpec ⇒ bit-identical outcome, run to run: the
    fault plane draws from its OWN rng stream and the recovery story is
    replayable."""
    sess, res, loads = joint
    fs = FaultSpec(
        device_failures=(DeviceFailure(time=t_fail, device=victim),),
        straggles=(Straggle(time=0.25, device=(victim + 1) % 3,
                            factor=3.0, until=1.0),),
        transient=TransientErrors(rate=0.1, start=0.5), seed=seed,
        max_retries=1)
    a = sess.simulate(loads, sim=SIM, faults=fs)
    b = sess.simulate(loads, sim=SIM,
                      faults=FaultSpec.from_dict(fs.to_dict()))
    assert _fingerprint(a) == _fingerprint(b)
    assert a.heartbeats == b.heartbeats


# --------------------------------------------------------------------------
# device_mask: all four solver modes place only on survivors
# --------------------------------------------------------------------------

def _placed_devices(res):
    return {d for placed in res.allocation.placement.per_stage
            for d, _ in placed}


@pytest.mark.parametrize("mode", ["vectorized", "incremental", "jax"])
def test_device_mask_modes_single_tenant(mode):
    graph = camelot_suite()["img-to-img"]
    pred = PipelinePredictor.from_graph(graph, RTX_2080TI, seed=0)
    sa = SAConfig(iterations=400, seed=0, mode=mode)
    alloc = CamelotAllocator(graph, pred, RTX_2080TI, 3, sa=sa)
    masked = alloc.solve_max_load(8, device_mask=[0, 2])
    assert masked.feasible and _placed_devices(masked) <= {0, 2}
    # the masked solve IS the shrunk-pool solve, remapped onto survivors
    small = CamelotAllocator(graph, pred, RTX_2080TI, 2, sa=sa)\
        .solve_max_load(8)
    assert masked.objective == small.objective
    assert masked.allocation.placement.per_stage == \
        _remap_placement(small.allocation, [0, 2]).placement.per_stage
    # masking restores the pool afterwards
    assert alloc.n_devices == 3
    full = alloc.solve_max_load(8)
    assert _placed_devices(full) <= {0, 1, 2}


def test_device_mask_joint_and_hierarchical(joint):
    sess, res, loads = joint
    pred = sess._require_predictor()
    joint_alloc = MultiTenantAllocator(
        sess.tenant_set, pred, sess.cluster.device_spec, 3,
        comm=sess.cluster.comm_model(), sa=SA)
    masked = joint_alloc.solve_max_load(8, device_mask=[1, 2])
    assert masked.feasible and _placed_devices(masked) <= {1, 2}
    tgt = [0.3 * res.objective] * 2
    mres = joint_alloc.solve_min_resource(8, tgt, device_mask=[1, 2])
    assert mres.feasible and _placed_devices(mres) <= {1, 2}
    hier = HierarchicalSolver(sess.tenant_set, pred,
                              sess.cluster.device_spec, 3,
                              comm=sess.cluster.comm_model(), sa=SA)
    hmasked = hier.solve_max_load(8, device_mask=[1, 2])
    assert hmasked.feasible and _placed_devices(hmasked) <= {1, 2}
    assert hier.n_devices == 3                    # pool restored


# --------------------------------------------------------------------------
# degradation sheds strictly in priority-weight order
# --------------------------------------------------------------------------

def _stub_runtime(weights, feasible_after_sheds):
    """A MultiTenantRuntime wired to a stub allocator whose min-resource
    solve goes feasible only once ``feasible_after_sheds`` targets have
    been floored — isolates the degradation loop from the SA solver."""
    g = camelot_suite()["img-to-img"]
    tenants = TenantSet([Tenant(f"t{i}", g, weight=w)
                         for i, w in enumerate(weights)])
    alloc = Allocation(stages=[StageAlloc(1, 0.5, 8)],
                      placement=Placement(per_stage=[[(0, 0.5)]]))

    class _Stub:
        def __init__(self):
            self.min_calls = []

        def solve_max_load(self, batch, warm_start=None, device_mask=None):
            return types.SimpleNamespace(
                feasible=True, objective=100.0, allocation=alloc,
                warm_started=warm_start is not None, solve_time=0.0)

        def solve_min_resource(self, batch, targets, warm_start=None,
                               device_mask=None):
            self.min_calls.append(list(targets))
            ok = sum(1 for t in targets if t <= 1.0) >= feasible_after_sheds
            return types.SimpleNamespace(
                feasible=ok, objective=-1.0 if ok else 0.0,
                allocation=alloc, warm_started=warm_start is not None,
                solve_time=0.0)

    rt = MultiTenantRuntime.__new__(MultiTenantRuntime)
    rt.tenants = tenants
    rt.rt = RuntimeConfig(ewma_alpha=1.0, headroom=1.0)
    rt.n_devices = 3
    rt.batch = 8
    rt.allocator = _Stub()
    rt.peak_result = rt.allocator.solve_max_load(8)
    rt.peak_lambda = 100.0
    rt._load_est = [50.0] * len(weights)
    rt.current = alloc
    rt.last_result = rt.peak_result
    rt.history = []
    rt._engine = None
    return rt


def test_degradation_sheds_in_weight_order():
    rt = _stub_runtime(weights=[1.0, 0.25, 0.5], feasible_after_sheds=2)
    rt.on_device_failure(5.0, [2])
    ev = rt.history[-1]
    assert ev.reason == "degraded"
    # lowest weight first (t1 w=0.25, then t2 w=0.5); t0 survives
    assert ev.shed == ("t1", "t2")
    floored = [[i for i, t in enumerate(c) if t <= 1.0]
               for c in rt.allocator.min_calls]
    assert floored == [[], [1], [1, 2]]           # strictly one at a time


def test_no_shed_when_masked_solve_fits():
    rt = _stub_runtime(weights=[1.0, 0.25], feasible_after_sheds=0)
    rt.on_device_failure(5.0, [2])
    ev = rt.history[-1]
    assert ev.reason == "device_failure" and ev.shed == ()
    assert ev.feasible


def test_reallocation_event_roundtrip():
    ev = ReallocationEvent(time=3.0, load_estimate=50.0,
                           provisioned_for=55.0, total_quota=1.5,
                           feasible=True, objective=-1.5,
                           warm_started=True, reason="degraded",
                           shed=("a", "b"))
    assert ReallocationEvent.from_dict(ev.to_dict()) == ev
    # events persisted before the fault plane existed load with defaults
    old = {"time": 1.0, "load_estimate": 2.0, "provisioned_for": 3.0,
           "total_quota": 0.5, "feasible": True}
    back = ReallocationEvent.from_dict(old)
    assert back.reason == "load" and back.shed == ()


# --------------------------------------------------------------------------
# health monitor
# --------------------------------------------------------------------------

def test_health_monitor_detects_silent_device():
    mon = HealthMonitor(range(3), heartbeat_timeout=0.4)
    mon.observe(1.0, {0: 0.9, 1: 0.95, 2: 0.99})
    assert mon.dead_devices(1.0) == []
    # device 1 goes silent; the others keep beating
    mon.observe(2.0, {0: 1.9, 1: 1.1, 2: 1.95})
    assert mon.dead_devices(2.0) == [1]
    mon.mark_dead(2)
    assert mon.dead_devices(2.0) == [1, 2]
    # a device never seen alive is unproven, not dead
    assert 3 not in mon.dead_devices(2.0)


def test_health_monitor_straggle_scores():
    mon = HealthMonitor(range(3), heartbeat_timeout=10.0,
                        ewma_alpha=1.0, straggle_factor=3.0)
    for k in range(1, 6):
        mon.observe(k * 1.0, {0: k * 0.1, 1: k * 0.1, 2: k * 0.5})
    scores = mon.straggle_scores()
    assert scores[2] > scores[0]
    assert mon.stragglers() == [2]
    assert mon.dead_devices(5.0) == []            # slow, not dead


# --------------------------------------------------------------------------
# exec core: kill/abandon bookkeeping
# --------------------------------------------------------------------------

def _exec_core(per_stage, batch=2, timeout=0.0):
    return ExecCore(len(per_stage), Placement(per_stage=per_stage),
                    BatchingPolicy(batch, timeout))


def test_kill_device_removes_instances_from_dispatch():
    core = _exec_core([[(0, 0.5), (1, 0.5)]], batch=1)
    assert core.kill_device(0) == 1
    assert core.alive_instances(0) == 1
    for q in ("a", "b"):
        core.admit(q, 0.0)
    core.form_batches(0.0)
    got = core.dispatch(0.0)
    assert len(got) == 1 and got[0][0].device == 1
    # releasing a dead instance never re-enters the free pool
    dead = next(i for i in core.stage_instances[0] if i.device == 0)
    dead.busy = True
    core.release(dead)
    assert all(inst.device == 1 for inst, _ in core.dispatch(0.0))


def test_abandon_poisons_joins_and_exit():
    core = _exec_core([[(0, 1.0)]], batch=1)
    core.admit("a", 0.0)
    core.form_batches(0.0)
    [(inst, rb)] = core.dispatch(0.0)
    core.release(inst)                            # engine order: release,
    core.abandon(rb.bid)                          # then abandon
    core.abandon(rb.bid)                          # idempotent
    assert core.complete_exit(rb.bid, 0) is False
    assert not core.has_work()


# --------------------------------------------------------------------------
# live engines: deadlock regression, retry, deadline, fold parity
# --------------------------------------------------------------------------

class SleepStage:
    def __init__(self, service_time=0.02, vocab=16):
        self.service_time = service_time
        self.cfg = types.SimpleNamespace(vocab_size=vocab)
        self.calls = 0

    def warmup(self, batch):
        pass

    def process(self, tokens):
        time.sleep(self.service_time)
        self.calls += 1
        return np.zeros((tokens.shape[0],), np.int32)


class FailingStage(SleepStage):
    """Raises on the first ``fail_first`` process calls, then succeeds."""

    def __init__(self, fail_first=10 ** 9, **kw):
        super().__init__(**kw)
        self.fail_first = fail_first

    def process(self, tokens):
        self.calls += 1
        if self.calls <= self.fail_first:
            raise RuntimeError("injected stage fault")
        return super().process(tokens)


def _burst(n):
    return [Query(qid=i, arrival=0.0, tokens=np.zeros(8, np.int32))
            for i in range(n)]


def _run_with_watchdog(fn, timeout=20.0):
    """The pre-fix engine deadlocked on a raising worker; run the trace on
    a side thread so a regression fails the test instead of hanging it."""
    box = {}

    def target():
        box["stats"] = fn()

    th = threading.Thread(target=target, daemon=True)
    th.start()
    th.join(timeout)
    assert not th.is_alive(), "engine deadlocked on worker exception"
    return box["stats"]


def test_worker_exception_drains_not_deadlocks():
    eng = PipelineEngine([FailingStage()],
                         allocation=default_allocation(1, batch=2),
                         qos_target=2.0, batch_timeout=0.005)
    stats = _run_with_watchdog(lambda: eng.run_trace(_burst(4)))
    assert stats.failed == 4
    assert stats.qos.count() == 0


def test_worker_retry_recovers():
    stage = FailingStage(fail_first=2)
    eng = PipelineEngine([stage], allocation=default_allocation(1, batch=4),
                         qos_target=5.0, batch_timeout=0.005,
                         max_retries=2, retry_backoff=0.0)
    stats = _run_with_watchdog(lambda: eng.run_trace(_burst(4)))
    assert stats.failed == 0
    assert stats.retries >= 2
    assert stats.qos.count() == 4


def test_retry_backoff_does_not_idle_the_instance():
    """Regression for the non-blocking retry queue: a batch waiting out its
    backoff used to SLEEP inside the worker slot, idling the instance.  With
    the driver-side timed requeue, the three healthy single-query batches
    must complete while the failed batch is still backing off."""
    stage = FailingStage(fail_first=1, service_time=0.005)
    eng = PipelineEngine([stage], allocation=default_allocation(1, batch=1),
                         qos_target=5.0, batch_timeout=0.0,
                         max_retries=1, retry_backoff=0.3)
    stats = _run_with_watchdog(lambda: eng.run_trace(_burst(4)))
    assert stats.failed == 0 and stats.retries == 1
    assert stats.qos.count() == 4
    lat = sorted(stats.qos.latencies)      # arrival 0.0: latency == done time
    # healthy queries finished on the free instance DURING the backoff...
    assert all(t < 0.25 for t in lat[:3]), lat
    # ...and the retried one completed only after the 0.3 s backoff elapsed
    assert lat[3] >= 0.3, lat


def test_deadline_abandons_stale_queries():
    eng = PipelineEngine([SleepStage()],
                         allocation=default_allocation(1, batch=4),
                         qos_target=5.0, batch_timeout=0.5, deadline=0.05)
    stage = eng.stages[0]
    # 2 queries never fill the 4-batch; the 0.5 s batch timeout sits far
    # past the 50 ms deadline, so both are abandoned before dispatch
    stats = _run_with_watchdog(lambda: eng.run_trace(_burst(2)))
    assert stats.failed == 2
    assert stats.qos.count() == 0 and stage.calls == 0


def test_pipeline_engine_is_one_tenant_delegation():
    """Satellite: PipelineEngine is the one-tenant face of
    MultiTenantEngine — same driver loop, shared state, same contract."""
    eng = PipelineEngine([SleepStage()],
                         allocation=default_allocation(1, batch=2),
                         qos_target=2.0, batch_timeout=0.005)
    assert isinstance(eng._inner, MultiTenantEngine)
    assert eng.alloc is eng._inner.tenants[0].alloc
    assert eng.channels is eng._inner.tenants[0].channels
    stats = eng.run_trace(_burst(6))
    assert stats.qos.count() == 6 and stats.batches == 3
    two = Allocation(stages=[StageAlloc(2, 0.5, 2)],
                     placement=Placement(per_stage=[[(0, 0.5), (0, 0.5)]]))
    eng.apply_allocation(two)
    stats2 = eng.run_trace(_burst(4))
    assert stats2.qos.count() == 4
    assert eng.swaps == 1
    assert len(eng.alloc.placement.per_stage[0]) == 2


# --------------------------------------------------------------------------
# crash-restart: resume from persistence with NO cold solve
# --------------------------------------------------------------------------

def test_kill_and_restart_resumes_without_cold_solve(joint, tmp_path,
                                                     monkeypatch):
    sess, res, loads = joint
    path = str(tmp_path / "sess.json")
    sess.save(path)

    back = MultiServiceSession.load(path)         # the restarted process
    assert back.last_result is not None and back.last_result.feasible

    def _boom(self, *a, **kw):
        raise AssertionError("cold solve after restart")

    monkeypatch.setattr(MultiTenantAllocator, "solve_max_load", _boom)
    rt = back.runtime(rt=RuntimeConfig(ewma_alpha=1.0), sa=SA, resume=True)
    assert rt.peak_lambda == res.objective
    assert rt.current.placement is not None
    # the resumed incumbent simulates identically to the pre-crash one
    monkeypatch.undo()
    a = sess.simulate(loads, sim=SIM)
    b = back.simulate(loads, sim=SIM)
    assert _fingerprint(a) == _fingerprint(b)


def test_runtime_without_resume_still_solves(joint, monkeypatch):
    sess, res, loads = joint
    calls = []
    real = MultiTenantAllocator.solve_max_load

    def _spy(self, *a, **kw):
        calls.append(1)
        return real(self, *a, **kw)

    monkeypatch.setattr(MultiTenantAllocator, "solve_max_load", _spy)
    fresh = MultiServiceSession(
        [Tenant("img-to-img", camelot_suite()["img-to-img"]),
         Tenant("diamond", dag_suite()["diamond"])],
        ClusterSpec(devices=3), batch=8, name="cold")
    fresh.profile()
    fresh.runtime(sa=SA)                          # no resume: cold solve
    assert calls
