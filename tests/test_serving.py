"""Live serving engine: trace replay, both communication mechanisms,
allocation-driven execution, profiling feed into the predictor."""
import numpy as np
import pytest

from repro.core import HOST_STAGED, RTX_2080TI, profile_from_engine
from repro.core.types import Allocation, Placement, StageAlloc
from repro.serving import ModelStageServer, PipelineEngine, make_trace


@pytest.fixture(scope="module")
def stages():
    return [ModelStageServer("s0", "qwen3-0.6b", seq_len=16),
            ModelStageServer("s1", "qwen1.5-0.5b", seq_len=16)]


def _fresh_trace(stages, n=10, qps=50):
    return make_trace(n, qps=qps, seq_len=16,
                      vocab=stages[0].cfg.vocab_size, seed=1)


def test_engine_completes_all_queries(stages):
    eng = PipelineEngine(stages, comm_mechanism="device", qos_target=2.0,
                         batch_size=4, batch_timeout=0.02)
    stats = eng.run_trace(_fresh_trace(stages))
    s = stats.summary()
    assert s["completed"] == 10
    assert s["p99"] > 0


def test_host_mechanism_moves_bytes(stages):
    eng = PipelineEngine(stages, comm_mechanism="host", qos_target=2.0,
                         batch_size=4, batch_timeout=0.02)
    stats = eng.run_trace(_fresh_trace(stages))
    assert stats.comm_time > 0
    assert eng.channels[0].bytes_moved > 0


def test_device_mechanism_zero_copy(stages):
    eng = PipelineEngine(stages, comm_mechanism="device", qos_target=2.0,
                         batch_size=4, batch_timeout=0.02)
    stats = eng.run_trace(_fresh_trace(stages))
    assert eng.channels[0].transfers > 0     # handles passed, no bytes field


def test_engine_consumes_allocation_with_placement(stages):
    """The live engine executes the allocator's output: a 2-instance stage-0
    with explicit placement, with per-edge auto mechanism selection."""
    alloc = Allocation(
        stages=[StageAlloc(2, 0.25, 4), StageAlloc(1, 0.5, 4)],
        placement=Placement(per_stage=[[(0, 0.25), (0, 0.25)], [(0, 0.5)]]))
    eng = PipelineEngine(stages, allocation=alloc, comm_mechanism="auto",
                         qos_target=2.0, batch_timeout=0.02)
    stats = eng.run_trace(_fresh_trace(stages))
    assert stats.summary()["completed"] == 10
    # the (B,) next-token payload sits below the Fig. 11 crossover, so the
    # auto route must pick host-staging for this edge
    assert eng.channels[0].picks[HOST_STAGED] > 0


def test_profiling_feed_builds_profile(stages):
    timings = stages[0].profile_stage_timings(batches=(1, 2, 4), repeats=2)
    assert len(timings) == 3
    assert all(t > 0 for _, t in timings)
    prof = profile_from_engine("s0", timings, weights_bytes=1e9,
                               act_bytes_per_query=1e7, device=RTX_2080TI)
    assert prof.flops_per_query > 0
    d = prof.duration(4, 1.0, RTX_2080TI)
    assert d > 0
