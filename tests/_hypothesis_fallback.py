"""Example-based fallback for the small slice of the `hypothesis` API the
test-suite uses (``given`` / ``settings`` / ``strategies``).

The container this repo is verified in does not ship ``hypothesis``; rather
than skipping every property test, modules import it as

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st

and transparently degrade to a deterministic example sweep: each strategy
exposes a handful of representative values (both endpoints + midpoints), and
``given`` runs the test body over the all-minimal combination plus a seeded
random sample of the cartesian product, capped at ``settings(max_examples=)``.
No shrinking, no database — but the same test code exercises the same
parameter space either way.
"""
from __future__ import annotations

import inspect
import itertools

import numpy as np


class _Strategy:
    def __init__(self, examples):
        # de-duplicate preserving order (integers(0, 1) -> [0, 1], not [0,0,1])
        seen, out = set(), []
        for e in examples:
            key = repr(e)
            if key not in seen:
                seen.add(key)
                out.append(e)
        self.examples = out


class strategies:
    """Minimal stand-ins for hypothesis.strategies.*"""

    @staticmethod
    def integers(min_value, max_value):
        mid = (min_value + max_value) // 2
        lo_mid = (min_value + mid) // 2
        hi_mid = (mid + max_value) // 2
        return _Strategy([min_value, max_value, mid, lo_mid, hi_mid])

    @staticmethod
    def sampled_from(elements):
        return _Strategy(list(elements))

    @staticmethod
    def booleans():
        return _Strategy([False, True])

    @staticmethod
    def floats(min_value, max_value, **_kw):
        return _Strategy([min_value, max_value,
                          0.5 * (min_value + max_value)])


st = strategies


def settings(**kw):
    """Records max_examples on the decorated function (wrapper or raw)."""
    max_examples = kw.get("max_examples", 12)

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    names = list(strats)

    def deco(fn):
        def wrapper(*args, **kwargs):
            max_ex = getattr(wrapper, "_fallback_max_examples",
                             getattr(fn, "_fallback_max_examples", 12))
            pools = [strats[n].examples for n in names]
            total = 1
            for p in pools:
                total *= len(p)
            combos = [tuple(p[0] for p in pools)]       # the minimal example
            seen = {repr(combos[0])}
            if total <= max_ex:
                for c in itertools.product(*pools):
                    if repr(c) not in seen:
                        seen.add(repr(c))
                        combos.append(c)
            else:
                rng = np.random.default_rng(0)
                attempts = 0
                while len(combos) < max_ex and attempts < 50 * max_ex:
                    c = tuple(p[int(rng.integers(len(p)))] for p in pools)
                    attempts += 1
                    if repr(c) not in seen:
                        seen.add(repr(c))
                        combos.append(c)
            for c in combos:
                fn(*args, **dict(zip(names, c)), **kwargs)

        # hide the strategy-filled parameters from pytest's fixture resolver
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
