"""ServiceGraph semantics across the stack: topology validation, fan-in
join barriers under out-of-order branch completion, multi-exit completion,
critical-path Constraint-5 vs simulator-measured latency, chain
equivalence with the pre-DAG linear engine/simulator, and a diamond
end-to-end through allocator -> packer -> simulator AND live engine."""
import time
import types

import numpy as np
import pytest

from repro.core import (RTX_2080TI, BatchingPolicy, CamelotAllocator,
                        CommModel, ExecCore, PipelinePredictor, SAConfig,
                        ServiceEdge, ServiceGraph, edge_bytes)
from repro.core.types import (Allocation, MicroserviceProfile, Pipeline,
                              Placement, StageAlloc)
from repro.serving import PipelineEngine, Query
from repro.sim import PipelineSimulator, SimConfig, dag_suite, even_allocation
from repro.sim.workloads import artifact_pipelines, camelot_suite


def _prof(name, flops=10e9, host=1e6):
    return MicroserviceProfile(
        name=name, flops_per_query=flops, mem_bytes_per_query=40e6,
        host_bytes_per_query=host, weights_bytes=500e6,
        act_bytes_per_query=24e6, overhead=1e-3, serial_frac=0.05)


def _diamond(qos=0.5):
    nodes = [_prof("extract"), _prof("caption", flops=20e9),
             _prof("classify", flops=5e9), _prof("fuse", flops=2e9)]
    edges = [ServiceEdge(0, 1), ServiceEdge(0, 2),
             ServiceEdge(1, 3), ServiceEdge(2, 3)]
    return ServiceGraph("diamond", nodes, edges, qos_target=qos)


# --------------------------------------------------------------------------
# topology
# --------------------------------------------------------------------------

def test_chain_is_special_case():
    stages = [_prof("a"), _prof("b"), _prof("c")]
    g = ServiceGraph.chain("svc", stages, qos_target=0.3)
    assert g.is_chain and g.entries == [0] and g.exits == [2]
    assert g.topo_order == [0, 1, 2]
    # Pipeline IS a chain ServiceGraph: old callers get graph semantics
    p = Pipeline("svc", stages, qos_target=0.3)
    assert isinstance(p, ServiceGraph) and p.is_chain
    assert p.n_stages == 3 and p.stages is p.nodes
    assert [(e.src, e.dst) for e in p.edges] == [(0, 1), (1, 2)]
    assert not _diamond().is_chain


def test_graph_validation():
    with pytest.raises(AssertionError):          # cycle
        ServiceGraph("cyc", [_prof("a"), _prof("b")],
                     [ServiceEdge(0, 1), ServiceEdge(1, 0)])
    with pytest.raises(AssertionError):          # dangling index
        ServiceGraph("bad", [_prof("a")], [ServiceEdge(0, 3)])
    with pytest.raises(AssertionError):          # duplicate edge
        ServiceGraph("dup", [_prof("a"), _prof("b")],
                     [ServiceEdge(0, 1), ServiceEdge(0, 1)])


def test_critical_path_picks_longest_branch():
    g = _diamond()
    cp = g.critical_path(node_cost=lambda i: [1.0, 5.0, 2.0, 1.0][i])
    assert cp == pytest.approx(1.0 + 5.0 + 1.0)  # through the slow branch
    cp_e = g.critical_path(node_cost=lambda i: 1.0,
                           edge_cost=lambda e: 10.0 if e.dst == 2 else 0.1)
    assert cp_e == pytest.approx(1.0 + 10.0 + 1.0 + 0.1 + 1.0)
    # chain reduces to the plain sum
    ch = ServiceGraph.chain("c", [_prof("a"), _prof("b")])
    assert ch.critical_path(lambda i: 2.0, lambda e: 0.5) == \
        pytest.approx(4.5)


def test_edge_bytes_explicit_fallback():
    # profiles that model host traffic: half in+out per query
    assert edge_bytes(_prof("x", host=4e6), 3) == pytest.approx(6e6)
    # no host traffic modelled: explicit 1 MB/query floor
    assert edge_bytes(_prof("x", host=0.0), 3) == pytest.approx(3e6)
    g = _diamond()
    assert g.edge_nbytes(0, 1, 2) == pytest.approx(1e6)  # half of 1 MB x2
    g2 = ServiceGraph("o", g.nodes, [ServiceEdge(0, 1, 7e3)] +
                      [e for e in g.edges if (e.src, e.dst) != (0, 1)])
    assert g2.edge_nbytes(0, 1, 2) == pytest.approx(14e3)  # override


# --------------------------------------------------------------------------
# fan-in join barrier (core level)
# --------------------------------------------------------------------------

def _graph_core(g, batch=2, timeout=0.0):
    n = g.n_nodes
    placement = Placement(per_stage=[[(0, round(1.0 / n, 4))]
                                     for _ in range(n)])
    return ExecCore(g, placement, BatchingPolicy(batch, timeout))


def test_fanin_join_out_of_order():
    core = _graph_core(_diamond())
    core.admit("q0", 0.0)
    core.admit("q1", 0.0)
    [rb] = core.form_batches(0.0)
    assert rb.stage == 0 and rb.bid == 0
    # the LATER branch (classify, node 2) finishes FIRST
    assert core.deliver(2, 3, rb.bid, rb.items, 1.0, data="from-2") is None
    assert core.has_work()                       # join holds the batch
    assert len(core.ready[3]) == 0
    joined = core.deliver(1, 3, rb.bid, rb.items, 2.0, data="from-1")
    assert joined is not None and joined.stage == 3
    assert joined.items == ["q0", "q1"]          # per-query order preserved
    assert joined.inputs == {1: "from-1", 2: "from-2"}
    assert len(core.ready[3]) == 1
    # a second batch joins independently of the first
    core.admit("q2", 0.0)
    core.admit("q3", 0.0)
    [rb2] = core.form_batches(0.0)
    assert rb2.bid == 1
    assert core.deliver(1, 3, rb2.bid, rb2.items, 3.0) is None
    assert core.deliver(2, 3, rb2.bid, rb2.items, 3.5) is not None


def test_fanin_rejects_duplicate_branch_delivery():
    core = _graph_core(_diamond())
    core.admit("q", 0.0)
    core.admit("q2", 0.0)
    [rb] = core.form_batches(0.0)
    core.deliver(1, 3, rb.bid, rb.items, 1.0)
    with pytest.raises(AssertionError):
        core.deliver(1, 3, rb.bid, rb.items, 1.1)


def test_multi_exit_completion():
    g = ServiceGraph("fan", [_prof("root"), _prof("h0"), _prof("h1")],
                     [ServiceEdge(0, 1), ServiceEdge(0, 2)])
    core = _graph_core(g)
    core.admit("a", 0.0)
    core.admit("b", 0.0)
    [rb] = core.form_batches(0.0)
    assert not core.complete_exit(rb.bid, 1)     # one head done: not yet
    assert core.complete_exit(rb.bid, 2)         # both heads: complete
    # chains complete at their single exit immediately
    cc = ExecCore(2, Placement(per_stage=[[(0, 0.5)], [(0, 0.5)]]),
                  BatchingPolicy(1, 0.0))
    cc.admit("x", 0.0)
    [crb] = cc.form_batches(0.0)
    assert cc.complete_exit(crb.bid, 1)


def test_route_on_placeholder_node_graph():
    """Engine-shaped graphs carry None profiles (the models live in the
    stage servers): the core must price their edges at the 1 MB/query
    default instead of dereferencing the missing profile."""
    g = ServiceGraph.chain("engine", [None, None], qos_target=2.0)
    core = ExecCore(g, Placement(per_stage=[[(0, 0.5)], [(0, 0.5)]]),
                    BatchingPolicy(2, 0.0), comm=CommModel(RTX_2080TI))
    r = core.route(0, 4, from_device=0)
    assert r.nbytes == pytest.approx(4e6)
    assert g.edge_nbytes(0, 1, 4) == pytest.approx(4e6)


def test_route_requires_dst_on_fanout():
    core = _graph_core(_diamond())
    r = core.route(0, 4, from_device=0, dst=1)
    assert (r.src, r.dst) == (0, 1) and r.same_device
    with pytest.raises(AssertionError):          # ambiguous successor
        core.route(0, 4, from_device=0)
    # single-successor nodes keep the chain-era call form
    r2 = core.route(1, 4, from_device=0)
    assert (r2.src, r2.dst) == (1, 3)


# --------------------------------------------------------------------------
# chain equivalence: the DAG core must reproduce PR 1's linear results
# --------------------------------------------------------------------------

# exact values measured on the pre-DAG (PR 1) simulator at these configs
_PR1_SNAPSHOT = {
    "img-to-img": (0.08064410520203903, 0.05453416021788585, 215, 36.0),
    "p2+c2+m2": (0.11991235245279838, 0.08107560788407363, 317, 52.6),
}


@pytest.mark.parametrize("name,qps", [("img-to-img", 40.0),
                                      ("p2+c2+m2", 60.0)])
def test_chain_simulation_bit_for_bit(name, qps):
    pipe = (camelot_suite() | artifact_pipelines())[name]
    for topo in (pipe, ServiceGraph.chain(pipe.name, pipe.nodes,
                                          qos_target=pipe.qos_target)):
        alloc, comm = even_allocation(topo, RTX_2080TI, 2, batch=8)
        r = PipelineSimulator(topo, alloc, RTX_2080TI, comm,
                              sim=SimConfig(duration=6.0, warmup=1.0,
                                            seed=0)).run(qps)
        assert (r.p99, r.mean_latency, r.completed, r.achieved_qps) == \
            _PR1_SNAPSHOT[name]


# --------------------------------------------------------------------------
# allocator: critical-path Constraint-5 vs simulated latency on a diamond
# --------------------------------------------------------------------------

def test_eval_critical_path_matches_simulator_on_diamond():
    g = _diamond(qos=1.0)
    # noise-free predictor on the sample grid -> DT reproduces ground truth
    pred = PipelinePredictor.from_graph(g, RTX_2080TI, noise=0.0)
    comm = CommModel(RTX_2080TI)
    alloc = CamelotAllocator(g, pred, RTX_2080TI, n_devices=1, comm=comm)
    ns = np.ones(4, dtype=np.int64)
    ps = np.full(4, 0.25)
    batch = 1
    ev = alloc._eval(ns, ps, batch, n_devices=1)
    assert ev is not None
    _, _, predicted_latency = ev
    # the critical path must run through the slow branch, not sum both
    durs = [pred.stages[i].duration(batch, 0.25) for i in range(4)]
    assert predicted_latency < sum(durs)
    assert predicted_latency > durs[0] + max(durs[1], durs[2]) + durs[3]

    stages = [StageAlloc(1, 0.25, batch) for _ in range(4)]
    placement = Placement(per_stage=[[(0, 0.25)] for _ in range(4)])
    a = Allocation(stages=stages, placement=placement)
    sim = PipelineSimulator(g, a, RTX_2080TI, comm,
                            sim=SimConfig(duration=8.0, warmup=1.0, seed=0,
                                          contention_noise=0.0))
    r = sim.run(3.0)                 # low load: no queueing, batch=1
    assert r.qos.count() > 10
    assert r.mean_latency == pytest.approx(predicted_latency, rel=0.15)


def test_allocator_end_to_end_on_dag_suite():
    for name, g in dag_suite().items():
        pred = PipelinePredictor.from_graph(g, RTX_2080TI,
                                            batches=(1, 4, 8, 16))
        comm = CommModel(RTX_2080TI)
        res = CamelotAllocator(g, pred, RTX_2080TI, 4, comm=comm,
                               sa=SAConfig(iterations=300)
                               ).solve_max_load(batch=8)
        assert res.feasible, name
        assert res.allocation.placement is not None
        assert len(res.allocation.placement.per_stage) == g.n_nodes
        r = PipelineSimulator(g, res.allocation, RTX_2080TI, comm,
                              sim=SimConfig(duration=4.0, warmup=0.5)
                              ).run(min(res.objective * 0.4, 40.0))
        assert r.completed > 0, name
        assert r.p99 <= g.qos_target * 2, (name, r.p99)


# --------------------------------------------------------------------------
# live engine on DAGs
# --------------------------------------------------------------------------

class RecordingStage:
    """Deterministic GIL-releasing stage; records the token prefixes it was
    fed so joins can be asserted on real data flow."""

    def __init__(self, service_time=0.01, out_val=1, seq_len=8, vocab=64):
        self.service_time = service_time
        self.out_val = out_val
        self.seq_len = seq_len
        self.cfg = types.SimpleNamespace(vocab_size=vocab)
        self.calls = 0
        self.seen = []

    def warmup(self, batch):
        pass

    def process(self, tokens):
        time.sleep(self.service_time)
        self.calls += 1
        self.seen.append(np.asarray(tokens)[:, 0].copy())
        return np.full((tokens.shape[0],), self.out_val, np.int32)


def _diamond_engine(branch_times=(0.05, 0.01), batch=2):
    g = ServiceGraph("diamond", [None] * 4,
                     [ServiceEdge(0, 1), ServiceEdge(0, 2),
                      ServiceEdge(1, 3), ServiceEdge(2, 3)], qos_target=5.0)
    stages = [RecordingStage(0.01, out_val=1),
              RecordingStage(branch_times[0], out_val=3),
              RecordingStage(branch_times[1], out_val=5),
              RecordingStage(0.01, out_val=7)]
    alloc = Allocation(stages=[StageAlloc(1, 0.25, batch) for _ in range(4)],
                       placement=Placement(
                           per_stage=[[(0, 0.25)] for _ in range(4)]))
    eng = PipelineEngine(stages, allocation=alloc, qos_target=5.0,
                         batch_timeout=0.01, graph=g)
    return eng, stages


def _burst(n):
    return [Query(qid=i, arrival=0.0, tokens=np.zeros(8, np.int32))
            for i in range(n)]


def test_engine_diamond_join_under_slow_branch():
    """Branch 1 is 5x slower than branch 2: the fuse node must still see
    BOTH branch outputs (sum 3+5=8) for every batch, in entry order."""
    eng, stages = _diamond_engine(branch_times=(0.05, 0.01))
    queries = _burst(6)
    stats = eng.run_trace(queries)
    assert stats.qos.count() == 6
    assert stats.batches == 3
    assert [s.calls for s in stages] == [3, 3, 3, 3]
    for prefix in stages[3].seen:                # fuse inputs: 3 + 5 = 8
        assert (prefix == 8).all()
    assert all(q.done is not None for q in queries)
    # per-query ordering: completion order of qids follows entry batches
    done_order = [q.qid for q in sorted(queries, key=lambda q: q.done)]
    assert done_order == sorted(done_order)


def test_engine_multi_exit_completes_once_all_heads_done():
    g = ServiceGraph("fan", [None] * 3,
                     [ServiceEdge(0, 1), ServiceEdge(0, 2)], qos_target=5.0)
    stages = [RecordingStage(0.01, out_val=1),
              RecordingStage(0.04, out_val=2),
              RecordingStage(0.01, out_val=4)]
    alloc = Allocation(stages=[StageAlloc(1, 0.3, 2) for _ in range(3)],
                       placement=Placement(
                           per_stage=[[(0, 0.3)] for _ in range(3)]))
    eng = PipelineEngine(stages, allocation=alloc, qos_target=5.0,
                         batch_timeout=0.01, graph=g)
    stats = eng.run_trace(_burst(4))
    assert stats.qos.count() == 4                # recorded once, not twice
    assert stats.batches == 2
    assert stages[1].calls == 2 and stages[2].calls == 2


def test_engine_chain_default_unchanged():
    """graph=None still builds the linear chain: same completions and the
    same number of stage calls as an explicit chain graph."""
    def run(graph):
        stages = [RecordingStage(0.01, out_val=2),
                  RecordingStage(0.01, out_val=3)]
        eng = PipelineEngine(stages, qos_target=5.0, batch_size=2,
                             batch_timeout=0.01, graph=graph)
        stats = eng.run_trace(_burst(4))
        return stats.qos.count(), [s.calls for s in stages], \
            [s.seen[0][0] for s in stages]

    implicit = run(None)
    explicit = run(ServiceGraph.chain("c", [None, None], qos_target=5.0))
    assert implicit == explicit == (4, [2, 2], [0, 2])
