"""Online runtime: diurnal load tracking + re-allocation loop."""
import numpy as np
import pytest

from repro.core import PipelinePredictor, RTX_2080TI, SAConfig
from repro.core.runtime import (CamelotRuntime, RuntimeConfig, diurnal_load)
from repro.sim.workloads import camelot_suite


@pytest.fixture(scope="module")
def runtime():
    pipe = camelot_suite()["img-to-img"]
    pred = PipelinePredictor.from_profiles(pipe.stages, RTX_2080TI)
    return CamelotRuntime(pipe, pred, RTX_2080TI, n_devices=2, batch=16,
                          rt=RuntimeConfig(reallocate_every=600.0,
                                           ewma_alpha=0.5),
                          sa=SAConfig(iterations=800, seed=0))


def test_quota_tracks_diurnal_load(runtime):
    load = diurnal_load(runtime.peak_qps * 0.9, period=3600.0)
    hist = runtime.run_trace(load, duration=3600.0, sample_every=60.0)
    assert len(hist) >= 5
    quotas = np.array([h.total_quota for h in hist])
    loads = np.array([h.load_estimate for h in hist])
    # provisioned quota must rise and fall with the load (positive corr)
    corr = np.corrcoef(loads[1:], quotas[1:])[0, 1]
    assert corr > 0.5, (corr, list(zip(loads, quotas)))
    # trough allocations use much less than the peak allocation
    assert quotas.min() < runtime.peak_result.allocation.total_quota() * 0.7


def test_switches_to_peak_allocation_near_capacity(runtime):
    runtime.history.clear()
    runtime._load_est = runtime.peak_qps * 0.95
    alloc = runtime.reallocate(now=0.0)
    assert alloc.total_quota() == pytest.approx(
        runtime.peak_result.allocation.total_quota())


def test_ewma_smoothing(runtime):
    runtime._load_est = 0.0
    runtime.observe(100.0)
    assert 0 < runtime.load_estimate < 100.0


def test_diurnal_shape():
    fn = diurnal_load(1000.0, period=86400.0, low_frac=0.25)
    assert fn(0) == pytest.approx(250.0, rel=0.01)            # trough
    assert fn(43200) == pytest.approx(1000.0, rel=0.01)       # midday peak
    assert 250 <= fn(20000) <= 1000
