"""Online runtime: diurnal load tracking + re-allocation loop."""
import numpy as np
import pytest

from repro.core import PipelinePredictor, RTX_2080TI, SAConfig
from repro.core.runtime import (CamelotRuntime, RuntimeConfig, diurnal_load)
from repro.sim.workloads import camelot_suite


@pytest.fixture(scope="module")
def runtime():
    pipe = camelot_suite()["img-to-img"]
    pred = PipelinePredictor.from_profiles(pipe.stages, RTX_2080TI)
    return CamelotRuntime(pipe, pred, RTX_2080TI, n_devices=2, batch=16,
                          rt=RuntimeConfig(reallocate_every=600.0,
                                           ewma_alpha=0.5),
                          sa=SAConfig(iterations=800, seed=0))


def test_quota_tracks_diurnal_load(runtime):
    load = diurnal_load(runtime.peak_qps * 0.9, period=3600.0)
    hist = runtime.run_trace(load, duration=3600.0, sample_every=60.0)
    assert len(hist) >= 5
    quotas = np.array([h.total_quota for h in hist])
    loads = np.array([h.load_estimate for h in hist])
    # provisioned quota must rise and fall with the load (positive corr)
    corr = np.corrcoef(loads[1:], quotas[1:])[0, 1]
    assert corr > 0.5, (corr, list(zip(loads, quotas)))
    # trough allocations use much less than the peak allocation
    assert quotas.min() < runtime.peak_result.allocation.total_quota() * 0.7


def test_switches_to_peak_allocation_near_capacity(runtime):
    runtime.history.clear()
    runtime._load_est = runtime.peak_qps * 0.95
    alloc = runtime.reallocate(now=0.0)
    assert alloc.total_quota() == pytest.approx(
        runtime.peak_result.allocation.total_quota())


def test_ewma_smoothing(runtime):
    runtime._load_est = 0.0
    runtime.observe(100.0)
    assert 0 < runtime.load_estimate < 100.0


def test_diurnal_shape():
    fn = diurnal_load(1000.0, period=86400.0, low_frac=0.25)
    assert fn(0) == pytest.approx(250.0, rel=0.01)            # trough
    assert fn(43200) == pytest.approx(1000.0, rel=0.01)       # midday peak
    assert 250 <= fn(20000) <= 1000


# ---------------------------------------------------------------------------
# Warm-started re-solves (the previous Allocation seeds an extra walker)
# ---------------------------------------------------------------------------

def test_warm_start_objective_ge_cold(runtime):
    """A warm-started min-resource solve must never come back worse than
    the cold solve of the same problem: the warm walker draws from its own
    RNG stream (the cold walkers' trajectories are untouched) and both
    incumbents get the deterministic polish."""
    load = runtime.peak_qps * 0.4
    cold = runtime.allocator.solve_min_resource(runtime.batch, load=load)
    warm = runtime.allocator.solve_min_resource(
        runtime.batch, load=load,
        warm_start=runtime.peak_result.allocation)
    assert not cold.warm_started
    assert warm.warm_started
    assert warm.feasible == cold.feasible
    assert warm.objective >= cold.objective - 1e-9


def test_runtime_warm_starts_diurnal_resolves():
    """Every min-resource re-solve along the diurnal trace is warm-started
    from the incumbent allocation and pinned >= the cold solve of the same
    target load."""
    pipe = camelot_suite()["img-to-img"]
    pred = PipelinePredictor.from_profiles(pipe.stages, RTX_2080TI)
    rt = CamelotRuntime(pipe, pred, RTX_2080TI, n_devices=2, batch=16,
                        rt=RuntimeConfig(reallocate_every=600.0,
                                         ewma_alpha=0.5),
                        sa=SAConfig(iterations=400, seed=0))
    load = diurnal_load(rt.peak_qps * 0.9, period=3600.0)
    hist = rt.run_trace(load, duration=3600.0, sample_every=60.0)
    warm_events = [e for e in hist if e.warm_started]
    assert warm_events, "the trough re-solves must run the solver"
    for ev in warm_events:
        cold = rt.allocator.solve_min_resource(
            rt.batch, load=max(ev.provisioned_for, 1.0))
        assert ev.objective >= cold.objective - 1e-9, \
            (ev.provisioned_for, ev.objective, cold.objective)


def test_warm_start_disabled_by_config():
    pipe = camelot_suite()["img-to-img"]
    pred = PipelinePredictor.from_profiles(pipe.stages, RTX_2080TI)
    rt = CamelotRuntime(pipe, pred, RTX_2080TI, n_devices=2, batch=16,
                        rt=RuntimeConfig(warm_start=False),
                        sa=SAConfig(iterations=400, seed=0))
    rt._load_est = rt.peak_qps * 0.3
    rt.reallocate(now=0.0)
    assert not rt.history[-1].warm_started
    assert not rt.last_result.warm_started
