"""Datacenter-scale solver: incremental evaluation parity, hierarchical
pod decomposition, the jitted annealing kernel, and cache bounds."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to deterministic example sweeps
    from _hypothesis_fallback import given, settings, st

from repro.core import (RTX_2080TI, CamelotAllocator, HierarchicalSolver,
                        MultiTenantAllocator, PipelinePredictor, PodConfig,
                        SAConfig)
from repro.core.incremental import IncrementalEvaluator
from repro.core.types import TenantSet
from repro.sim import multitenant_suite, synthetic_predictor, \
    synthetic_tenant_set
from repro.sim.workloads import camelot_suite


def _tenant_fixture(name="3-tenant-mixed"):
    tenants = TenantSet(multitenant_suite()[name])
    pred = PipelinePredictor.from_graph(tenants.union_graph, RTX_2080TI,
                                        seed=0)
    return tenants, pred


# --------------------------------------------------------------------------
# incremental evaluator == dense evaluator (the tentpole's correctness bar)
# --------------------------------------------------------------------------

@settings(max_examples=8)
@given(seed=st.integers(0, 10_000), steps=st.integers(1, 6))
def test_incremental_eval_matches_dense_on_random_mutations(seed, steps):
    """Random walker states + randomized <= max_mutations mutation rows,
    eval'd by the incremental engine and the dense ``_eval_many``, must
    agree on all four outputs — including across commits (cache folding)
    and the periodic rebase."""
    tenants, pred = _tenant_fixture()
    sa = SAConfig(iterations=10, seed=seed, mode="incremental")
    alloc = MultiTenantAllocator(tenants, pred, RTX_2080TI, 4, sa=sa)
    batch = 4
    tab = alloc._policy_tables(batch)
    engine = IncrementalEvaluator(alloc, tab, 4)
    assert engine.usable, "suite graphs must support the sparse engine"

    rng = np.random.default_rng(seed)
    n, g = tenants.n_nodes, len(tab.grid)
    W, C = 5, 2                      # walkers x candidates-per-walker
    n_mut = max(1, sa.max_mutations)
    NS_w = rng.integers(1, 4, size=(W, n))
    QI_w = rng.integers(0, g, size=(W, n))
    engine.rebase(NS_w, QI_w)
    base = np.repeat(np.arange(W), C)          # the anneal's row layout
    for _ in range(steps):
        NS = NS_w[base].copy()
        QI = QI_w[base].copy()
        for r in range(W * C):
            for i in rng.integers(0, n, size=rng.integers(1, n_mut + 1)):
                if rng.random() < 0.5:
                    NS[r, i] = rng.integers(1, 4)
                else:
                    QI[r, i] = rng.integers(0, g)
        t_i, q_i, l_i, f_i = engine.eval(NS, QI, base)
        t_d, q_d, l_d, f_d = alloc._eval_many(NS, QI, tab, 4)
        np.testing.assert_allclose(t_i, t_d, rtol=1e-9)
        np.testing.assert_allclose(q_i, q_d, rtol=1e-9)
        np.testing.assert_allclose(l_i, l_d, rtol=1e-9)
        np.testing.assert_array_equal(f_i, f_d)
        # each accepted walker folds one of ITS OWN candidate rows back
        # in (the anneal's contract: commit(w, r) has base[r] == w)
        acc = np.flatnonzero(rng.random(W) < 0.5)
        if acc.size:
            picked = acc * C + rng.integers(0, C, size=acc.size)
            engine.commit(acc, picked)
            NS_w[acc] = NS[picked]
            QI_w[acc] = QI[picked]


def test_incremental_mode_end_to_end_parity():
    """A full incremental-mode anneal returns the exact vectorized-mode
    result (same objective, bit-identical allocation)."""
    tenants, pred = _tenant_fixture()
    res = {}
    for mode in ("vectorized", "incremental"):
        sa = SAConfig(iterations=400, seed=3, mode=mode)
        res[mode] = MultiTenantAllocator(tenants, pred, RTX_2080TI, 4,
                                         sa=sa).solve_max_load(4)
    assert res["incremental"].mode == "incremental"
    assert res["incremental"].objective == res["vectorized"].objective
    assert res["incremental"].allocation.to_dict() == \
        res["vectorized"].allocation.to_dict()


# --------------------------------------------------------------------------
# hierarchical solver
# --------------------------------------------------------------------------

def test_hierarchical_one_pod_is_flat_bit_for_bit():
    tenants, pred = _tenant_fixture()
    sa = SAConfig(iterations=400, seed=3, mode="incremental")
    flat = MultiTenantAllocator(tenants, pred, RTX_2080TI, 4,
                                sa=sa).solve_max_load(4)
    hier = HierarchicalSolver(tenants, pred, RTX_2080TI, 4, sa=sa,
                              pods=PodConfig(pod_size=4)).solve_max_load(4)
    assert hier.objective == flat.objective
    assert hier.allocation.to_dict() == flat.allocation.to_dict()
    assert hier.pods is not None and len(hier.pods) == 1


def test_hierarchical_multi_pod_feasible_and_partitioned():
    tenants = synthetic_tenant_set(8, seed=7)
    pred = synthetic_predictor(tenants)
    sa = SAConfig(iterations=300, seed=0, mode="incremental")
    res = HierarchicalSolver(tenants, pred, RTX_2080TI, 8, sa=sa,
                             pods=PodConfig(pod_size=4, repair_rounds=1)
                             ).solve_max_load(4)
    assert res.feasible
    assert res.mode == "hierarchical"
    assert len(res.pods) == 2
    # every tenant lands in exactly one pod; pods tile the device range
    seen = [t for p in res.pods for t in p["tenants"]]
    assert sorted(seen) == sorted(t.name for t in tenants.tenants)
    spans = sorted(tuple(p["devices"]) for p in res.pods)
    assert spans[0][0] == 0 and spans[-1][1] == 8
    assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))
    # round-trips through the SolveResult dict (session persistence)
    from repro.core.allocator import SolveResult
    back = SolveResult.from_dict(res.to_dict())
    assert back.pods == res.pods and back.mode == res.mode


# --------------------------------------------------------------------------
# jitted annealing kernel
# --------------------------------------------------------------------------

def test_jax_kernel_within_tolerance_on_every_suite_workload():
    anneal_jax = pytest.importorskip("repro.core.anneal_jax")
    if not anneal_jax.HAVE_JAX:
        pytest.skip("jax not available")
    for name, tenants in multitenant_suite().items():
        ts = TenantSet(tenants)
        pred = PipelinePredictor.from_graph(ts.union_graph, RTX_2080TI,
                                            seed=0)
        out = {}
        for mode in ("vectorized", "jax"):
            sa = SAConfig(iterations=400, seed=3, mode=mode)
            out[mode] = MultiTenantAllocator(ts, pred, RTX_2080TI, 4,
                                             sa=sa).solve_max_load(4)
        assert out["jax"].mode == "jax", name
        assert out["jax"].feasible == out["vectorized"].feasible, name
        ratio = out["jax"].objective / out["vectorized"].objective
        assert ratio >= 0.98, f"{name}: jax objective ratio {ratio:.4f}"


# --------------------------------------------------------------------------
# cache bounds (long-running runtimes must hold a fixed footprint)
# --------------------------------------------------------------------------

def test_allocator_caches_bounded_across_1k_solves():
    suite = camelot_suite()
    pipe = suite["img-to-img"]
    pred = PipelinePredictor.from_graph(pipe, RTX_2080TI, seed=0)
    sa = SAConfig(iterations=4, seed=0, mode="vectorized")
    alloc = CamelotAllocator(pipe, pred, RTX_2080TI, 2, sa=sa)
    for k in range(1000):
        alloc.solve_max_load(batch=2 + (k % 40))   # 40 distinct batches
        assert len(alloc._tables_cache) <= alloc.TABLES_CACHE_MAX
        assert len(alloc._ffd_memo) <= alloc.FFD_MEMO_MAX
    # table cache saturates at its cap, not at the distinct-batch count
    assert len(alloc._tables_cache) == alloc.TABLES_CACHE_MAX


def test_ffd_memo_fifo_eviction():
    suite = camelot_suite()
    pipe = suite["img-to-img"]
    pred = PipelinePredictor.from_graph(pipe, RTX_2080TI, seed=0)
    alloc = CamelotAllocator(pipe, pred, RTX_2080TI, 2)
    alloc.FFD_MEMO_MAX = 64          # instance override shadows the class
    for k in range(500):
        alloc._ffd_cached([k, 1], 2)
    assert len(alloc._ffd_memo) == 64
    # the newest keys survived (FIFO evicts oldest first)
    assert (2, (499, 1)) in alloc._ffd_memo
    assert (2, (0, 1)) not in alloc._ffd_memo
