"""Unified pipeline-execution core: batching/dispatch policy, per-edge
mechanism selection (Fig. 11 crossover), allocation-driven concurrency in
the live engine, and live re-allocation swaps."""
import threading
import time
import types

import numpy as np
import pytest

from repro.core import (GLOBAL_MEMORY, HOST_STAGED, RTX_2080TI,
                        BatchingPolicy, CamelotAllocator, CommModel,
                        EdgeChannel, ExecCore, default_allocation,
                        mechanism_time, select_mechanism)
from repro.core.runtime import CamelotRuntime, RuntimeConfig
from repro.core.types import Allocation, Placement, StageAlloc
from repro.serving import PipelineEngine, Query


# --------------------------------------------------------------------------
# mechanism selection (satellite: crossover coverage)
# --------------------------------------------------------------------------

def test_crossover_matches_mechanism_times():
    """crossover_bytes() is exactly where global-memory starts beating the
    host-staged round trip."""
    cm = CommModel(RTX_2080TI)
    x = cm.crossover_bytes()
    assert x > 0
    assert cm.host_staged_time(0.9 * x) < cm.global_memory_time(0.9 * x)
    assert cm.host_staged_time(1.1 * x) > cm.global_memory_time(1.1 * x)
    assert cm.host_staged_time(x) == pytest.approx(
        cm.global_memory_time(x), rel=1e-9)


def test_select_mechanism_per_edge():
    cm = CommModel(RTX_2080TI)
    x = cm.crossover_bytes()
    # sub-crossover payload on one device: host-staging is cheaper
    assert select_mechanism(cm, 0.5 * x, same_device=True) == HOST_STAGED
    # above the crossover: global-memory hand-off
    assert select_mechanism(cm, 2.0 * x, same_device=True) == GLOBAL_MEMORY
    # different devices can never use the hand-off
    assert select_mechanism(cm, 2.0 * x, same_device=False) != GLOBAL_MEMORY
    # mechanism disabled (the paper's default systems): always host
    off = CommModel(RTX_2080TI, global_memory_enabled=False)
    assert select_mechanism(off, 2.0 * x, same_device=True) == HOST_STAGED
    # charged times agree with the CommModel curves
    assert mechanism_time(cm, HOST_STAGED, 1e6) == \
        pytest.approx(cm.host_staged_time(1e6))
    assert mechanism_time(cm, GLOBAL_MEMORY, 1e6) == \
        pytest.approx(cm.global_memory_time(1e6))


def test_edge_channel_routes_by_size():
    """Live channel: sub-crossover payloads go through the host-staged copy
    path, larger ones through the zero-copy hand-off."""
    import jax.numpy as jnp
    cm = CommModel(RTX_2080TI)
    x = cm.crossover_bytes()
    ch = EdgeChannel(cm)
    small = jnp.zeros(max(int(0.25 * x) // 4, 1), jnp.int32)
    big = jnp.zeros(int(4 * x) // 4, jnp.int32)
    ch.send(small)
    assert ch.picks[HOST_STAGED] == 1 and ch.bytes_moved > 0
    ch.send(big)
    assert ch.picks[GLOBAL_MEMORY] == 1
    assert ch.device_handoff.transfers == 1
    # cross-device on one live host: ICI collapses to the in-memory
    # hand-off, but a host-only CommModel must route through the copies
    off = EdgeChannel(CommModel(RTX_2080TI, global_memory_enabled=False))
    off.send(big, same_device=False)
    assert off.picks[HOST_STAGED] == 1
    # forced modes override the crossover rule
    dev = EdgeChannel(cm, force="device")
    dev.send(small)
    assert dev.picks[GLOBAL_MEMORY] == 1


# --------------------------------------------------------------------------
# core batching + dispatch
# --------------------------------------------------------------------------

def _core(per_stage, batch=2, timeout=0.1, **kw):
    return ExecCore(len(per_stage), Placement(per_stage=per_stage),
                    BatchingPolicy(batch, timeout), **kw)


def test_batching_size_and_timeout():
    core = _core([[(0, 1.0)]], batch=3, timeout=0.5)
    core.admit("a", 0.0)
    core.admit("b", 0.1)
    assert core.form_batches(0.2) == []            # not full, not timed out
    assert core.batch_deadline() == pytest.approx(0.5)
    core.admit("c", 0.3)                           # full -> immediate batch
    [rb] = core.form_batches(0.3)
    assert rb.items == ["a", "b", "c"]
    core.admit("d", 0.4)                           # partial, must time out
    assert core.form_batches(0.5) == []
    [rb2] = core.form_batches(0.95)
    assert rb2.items == ["d"]
    assert core.batches_formed == 2


def test_multi_instance_dispatch_against_placement():
    core = _core([[(0, 0.5), (1, 0.5)]], batch=1, timeout=0.0)
    for q in ("a", "b", "c"):
        core.admit(q, 0.0)
    core.form_batches(0.0)
    got = core.dispatch(0.0)
    assert len(got) == 2                           # both instances busy
    assert {inst.device for inst, _ in got} == {0, 1}
    assert core.dispatch(0.0) == []                # third batch must wait
    core.release(got[0][0], busy_for=0.05)
    got2 = core.dispatch(0.0)
    assert len(got2) == 1
    assert got2[0][0].busy_time == pytest.approx(0.05)
    assert core.has_work()


def test_route_uses_placement_colocation():
    cm = CommModel(RTX_2080TI)
    x = cm.crossover_bytes()
    core = _core([[(0, 0.5)], [(0, 0.25), (1, 0.25)]],
                 comm=cm, edge_nbytes=lambda e, c: 4 * x * c)
    r = core.route(0, 1, from_device=0)
    assert r.same_device and r.mechanism == GLOBAL_MEMORY
    r2 = core.route(0, 1, from_device=7)           # producer off-placement
    assert not r2.same_device and r2.mechanism != GLOBAL_MEMORY
    tiny = _core([[(0, 0.5)], [(0, 0.5)]],
                 comm=cm, edge_nbytes=lambda e, c: 0.1 * x)
    assert tiny.route(0, 1, from_device=0).mechanism == HOST_STAGED


def test_reset_instances_swaps_pool_keeps_queues():
    core = _core([[(0, 1.0)]], batch=1, timeout=0.0)
    core.admit("a", 0.0)
    core.form_batches(0.0)
    [(inst, _)] = core.dispatch(0.0)
    core.admit("b", 0.0)
    core.form_batches(0.0)
    core.reset_instances(Placement(per_stage=[[(0, 0.5), (0, 0.5)]]))
    assert len(core.stage_instances[0]) == 2
    assert len(core.ready[0]) == 1                 # queued work survives
    core.release(inst)                             # old instance: no-op
    assert len(core.dispatch(0.0)) == 1


# --------------------------------------------------------------------------
# live engine: allocation-driven concurrency (acceptance criterion)
# --------------------------------------------------------------------------

class SleepStage:
    """Deterministic GIL-releasing stage: isolates the engine's concurrency
    from model-compute noise."""

    def __init__(self, service_time=0.06, seq_len=8, vocab=16):
        self.service_time = service_time
        self.seq_len = seq_len
        self.cfg = types.SimpleNamespace(vocab_size=vocab)
        self.calls = 0

    def warmup(self, batch):
        pass

    def process(self, tokens):
        time.sleep(self.service_time)
        self.calls += 1
        return np.zeros((tokens.shape[0],), np.int32)


def _burst_trace(n):
    return [Query(qid=i, arrival=0.0, tokens=np.zeros(8, np.int32))
            for i in range(n)]


def _two_instance_alloc(batch=2):
    return Allocation(stages=[StageAlloc(2, 0.5, batch)],
                      placement=Placement(per_stage=[[(0, 0.5), (0, 0.5)]]))


def test_two_instances_beat_one_on_p99():
    """A 2-instance stage completes the same burst with lower p99 than a
    single instance — N_i concurrency through the thread pool is real."""
    def p99(alloc):
        eng = PipelineEngine([SleepStage()], allocation=alloc,
                             qos_target=2.0, batch_timeout=0.005)
        stats = eng.run_trace(_burst_trace(8))
        assert stats.qos.count() == 8
        return stats.qos.tail_latency()

    p1 = p99(default_allocation(1, batch=2))       # 4 batches, serial
    p2 = p99(_two_instance_alloc(batch=2))         # 2 deep, 2 wide
    assert p2 < p1 * 0.8, (p1, p2)


def test_live_reallocation_swap_mid_trace():
    """CamelotRuntime-style reallocation applies to a RUNNING engine:
    allocations swap between batches and the trace still completes."""
    eng = PipelineEngine([SleepStage(service_time=0.04)],
                         allocation=default_allocation(1, batch=2),
                         qos_target=5.0, batch_timeout=0.005)
    timer = threading.Timer(0.06,
                            lambda: eng.apply_allocation(_two_instance_alloc()))
    timer.start()
    queries = _burst_trace(12)
    stats = eng.run_trace(queries)
    timer.join()
    assert stats.qos.count() == 12
    assert eng.swaps == 1
    assert len(eng.alloc.placement.per_stage[0]) == 2


def test_runtime_pushes_allocation_into_attached_engine():
    class _FakeEngine:
        def __init__(self):
            self.applied = []

        def apply_allocation(self, alloc):
            self.applied.append(alloc)

    rt = CamelotRuntime.__new__(CamelotRuntime)    # skip the SA solve
    rt.rt = RuntimeConfig()
    rt.peak_qps = 100.0
    rt.peak_result = types.SimpleNamespace(
        allocation=Allocation(stages=[StageAlloc(1, 1.0, 4)],
                              placement=Placement(per_stage=[[(0, 1.0)]])),
        feasible=True, objective=100.0, warm_started=False)
    rt._load_est = 95.0
    rt.current = rt.peak_result.allocation
    rt.history = []
    rt._engine = _FakeEngine()
    alloc = rt.reallocate(now=0.0)
    assert rt._engine.applied == [alloc]


# --------------------------------------------------------------------------
# config-default hygiene (satellite: shared-mutable-default fix)
# --------------------------------------------------------------------------

def test_allocator_sa_config_not_shared():
    from repro.sim.workloads import camelot_suite
    pipe = camelot_suite()["img-to-img"]
    a1 = CamelotAllocator(pipe, None, RTX_2080TI, 1)
    a1.sa.iterations = 7
    a2 = CamelotAllocator(pipe, None, RTX_2080TI, 1)
    assert a2.sa.iterations != 7


def test_sim_config_not_shared():
    from repro.sim.simulator import PipelineSimulator, SimConfig
    from repro.sim import even_allocation
    from repro.sim.workloads import camelot_suite
    pipe = camelot_suite()["img-to-img"]
    alloc, comm = even_allocation(pipe, RTX_2080TI, 2, batch=8)
    s1 = PipelineSimulator(pipe, alloc, RTX_2080TI, comm)
    s1.cfg.duration = 1.234
    s2 = PipelineSimulator(pipe, alloc, RTX_2080TI, comm)
    assert s2.cfg.duration != 1.234
    assert SimConfig().duration != 1.234
