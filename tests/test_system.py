"""End-to-end system behaviour: the full Camelot loop — profile (live) →
predict → allocate → simulate — plus the headline paper claims in band."""
import numpy as np
import pytest

from repro.core import (RTX_2080TI, CamelotAllocator, PipelinePredictor,
                        SAConfig, profile_from_engine)
from repro.sim import (PipelineSimulator, SimConfig, camelot, camelot_nc,
                       camelot_suite, even_allocation, find_peak_load)


def test_live_profile_to_allocation_roundtrip():
    """The paper's full pipeline: profile real (reduced) models on the live
    engine, fit the predictor, solve an allocation."""
    from repro.core.types import Pipeline
    from repro.serving import ModelStageServer
    stages = [ModelStageServer("sum", "qwen3-0.6b", seq_len=16),
              ModelStageServer("tr", "qwen1.5-0.5b", seq_len=16)]
    profs = []
    for st in stages:
        timings = st.profile_stage_timings(batches=(1, 2, 4), repeats=2)
        profs.append(profile_from_engine(
            st.name, timings, weights_bytes=1e9, act_bytes_per_query=2e7,
            device=RTX_2080TI, host_bytes_per_query=1e6))
    pipe = Pipeline("live", profs, qos_target=0.5)
    pred = PipelinePredictor.from_profiles(profs, RTX_2080TI)
    alloc = CamelotAllocator(pipe, pred, RTX_2080TI, n_devices=2,
                             sa=SAConfig(iterations=600, seed=0))
    res = alloc.solve_max_load(batch=8)
    assert res.feasible
    assert res.allocation.placement is not None


def test_headline_claim_peak_load_gain():
    """Paper: Camelot beats EA by 12-73.9% peak load.  We assert the gain is
    positive and substantial on two suite pipelines."""
    scfg = SimConfig(duration=8.0, warmup=1.0, seed=0)
    gains = []
    for name in ("img-to-img", "text-to-text"):
        pipe = camelot_suite()[name]
        pred = PipelinePredictor.from_profiles(pipe.stages, RTX_2080TI)
        a_ea, c_ea = even_allocation(pipe, RTX_2080TI, 2, 16)
        a_cm, c_cm, _ = camelot(pipe, pred, RTX_2080TI, 2, 16)
        p_ea, _ = find_peak_load(lambda a=a_ea, c=c_ea: PipelineSimulator(
            pipe, a, RTX_2080TI, c, scfg), pipe.qos_target)
        p_cm, _ = find_peak_load(lambda a=a_cm, c=c_cm: PipelineSimulator(
            pipe, a, RTX_2080TI, c, scfg), pipe.qos_target)
        gains.append(p_cm / max(p_ea, 1e-9) - 1)
    assert max(gains) > 0.10, gains


def test_headline_claim_resource_saving():
    """Paper: −35% to −46.5% resource usage at 30% load with QoS held."""
    from repro.sim import camelot_min_resource
    pipe = camelot_suite()["img-to-img"]
    pred = PipelinePredictor.from_profiles(pipe.stages, RTX_2080TI)
    a_cm, c_cm, res = camelot(pipe, pred, RTX_2080TI, 2, 16)
    low = res.objective * 0.3
    a_mr, c_mr, res_mr = camelot_min_resource(pipe, pred, RTX_2080TI, 2, 16,
                                              load=low)
    assert res_mr.feasible
    saving = 1 - a_mr.total_quota() / 2.0   # vs one GPU per stage (2 GPUs)
    assert saving > 0.3, saving
    # QoS must hold at the low load in simulation
    scfg = SimConfig(duration=8.0, warmup=1.0, seed=1)
    r = PipelineSimulator(pipe, a_mr, RTX_2080TI, c_mr, scfg).run(low)
    assert r.p99 <= pipe.qos_target * 1.05, r.p99


def test_camelot_nc_risks_qos():
    """Disabling Constraint-3 (Camelot-NC) must never *help* QoS; the paper
    sees violations in 10/16 cases."""
    pipe = camelot_suite()["img-to-text"]
    pred = PipelinePredictor.from_profiles(pipe.stages, RTX_2080TI)
    a_nc, c_nc, res_nc = camelot_nc(pipe, pred, RTX_2080TI, 2, 16)
    a_cm, c_cm, res_cm = camelot(pipe, pred, RTX_2080TI, 2, 16)
    # NC's claimed throughput is >= Camelot's (fewer constraints)
    assert res_nc.objective >= res_cm.objective - 1e-6
